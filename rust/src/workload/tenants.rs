//! Multi-tenant workload modeling: N tenants, each with its own
//! Table-2-length system prompt and an arrival share, interleaved into
//! one request stream.
//!
//! The paper's protocol serves a single system prompt; a production
//! fleet serves many.  Tenant prompt lengths cycle through the paper's
//! Table 2 (26472 / 7069 / 4759 tokens) so each group's shared stage
//! sits in the regime the paper characterizes, and arrival shares
//! follow a Zipf(`skew`) law — `skew = 0` is uniform traffic, larger
//! values concentrate arrivals on the head tenants (one hot group,
//! many cold ones).

use std::collections::VecDeque;

use crate::util::rng::Rng;

use super::datasets::{all_datasets, Dataset};
use super::generator::Request;

/// The paper's Table 2 system-prompt lengths (tokens).
pub const TABLE2_LENGTHS: [usize; 3] = [26472, 7069, 4759];

/// One tenant: a system prompt (its own prefix group) plus traffic.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub id: usize,
    pub name: String,
    /// System-prompt length, tokens (a Table-2 length).
    pub prompt_tokens: usize,
    /// Arrival share, normalized over the tenant set.
    pub share: f64,
    /// Length model of this tenant's questions/answers.
    pub dataset: Dataset,
}

impl TenantSpec {
    /// Deterministic synthetic prompt token ids — distinct per tenant
    /// (seeded by tenant id) so different tenants never collide in the
    /// radix tree, same discipline as `SystemPrompt::token_ids`.
    pub fn prompt_token_ids(&self, vocab: u32) -> Vec<u32> {
        let mut rng = Rng::new(0x7E4A_57A1u64 ^ (self.id as u64).wrapping_mul(0x9E37_79B9));
        (0..self.prompt_tokens).map(|_| rng.gen_range(0, vocab as u64) as u32).collect()
    }
}

/// Build `n` tenants with Zipf(`skew`) arrival shares (share_i ∝
/// 1/(i+1)^skew, normalized; `skew = 0` → uniform), prompt lengths and
/// datasets cycling through the paper's sets.
pub fn tenant_set(n: usize, skew: f64) -> Vec<TenantSpec> {
    assert!(n > 0, "at least one tenant");
    let datasets = all_datasets();
    let raw: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(skew)).collect();
    let total: f64 = raw.iter().sum();
    (0..n)
        .map(|i| TenantSpec {
            id: i,
            name: format!("tenant-{i}"),
            prompt_tokens: TABLE2_LENGTHS[i % TABLE2_LENGTHS.len()],
            share: raw[i] / total,
            dataset: datasets[i % datasets.len()].clone(),
        })
        .collect()
}

/// One arrival of the interleaved stream.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantRequest {
    pub tenant: usize,
    pub request: Request,
}

/// A finite multi-tenant request stream: per-tenant queues sampled from
/// each tenant's dataset, interleaved by weighted (share) picks from a
/// seeded RNG — fully deterministic per seed.
#[derive(Debug)]
pub struct MultiTenantGenerator {
    queues: Vec<VecDeque<Request>>,
    shares: Vec<f64>,
    rng: Rng,
    total: usize,
}

impl MultiTenantGenerator {
    /// Per-tenant request counts are `round(share x total_requests)`
    /// with a floor of 1 — every tenant sends *some* traffic, so every
    /// prefix group goes live.
    pub fn new(tenants: &[TenantSpec], total_requests: usize, seed: u64) -> Self {
        let mut queues = Vec::with_capacity(tenants.len());
        let mut next_id = 0u64;
        for t in tenants {
            let count = ((t.share * total_requests as f64).round() as usize).max(1);
            let mut rng_t = Rng::new(seed ^ (t.id as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F));
            let q: VecDeque<Request> = (0..count)
                .map(|_| {
                    let e = t.dataset.sample(&mut rng_t);
                    let r = Request {
                        id: next_id,
                        prompt_tokens: e.question_tokens,
                        max_new_tokens: e.answer_tokens,
                    };
                    next_id += 1;
                    r
                })
                .collect();
            queues.push(q);
        }
        let total = queues.iter().map(|q| q.len()).sum();
        MultiTenantGenerator {
            queues,
            shares: tenants.iter().map(|t| t.share).collect(),
            rng: Rng::new(seed.wrapping_mul(0x2545_F491_4F6C_DD1D).wrapping_add(1)),
            total,
        }
    }

    /// Next arrival: weighted pick among tenants with traffic left.
    pub fn next_request(&mut self) -> Option<TenantRequest> {
        let live: Vec<usize> =
            (0..self.queues.len()).filter(|&i| !self.queues[i].is_empty()).collect();
        if live.is_empty() {
            return None;
        }
        let total_w: f64 = live.iter().map(|&i| self.shares[i]).sum();
        let mut x = self.rng.next_f64() * total_w;
        let mut pick = *live.last().unwrap();
        for &i in &live {
            if x < self.shares[i] {
                pick = i;
                break;
            }
            x -= self.shares[i];
        }
        let request = self.queues[pick].pop_front().unwrap();
        Some(TenantRequest { tenant: pick, request })
    }

    pub fn remaining(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn is_exhausted(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Total tokens the full stream will generate (conservation checks).
    pub fn total_new_tokens(&self) -> usize {
        self.queues.iter().flatten().map(|r| r.max_new_tokens).sum()
    }
}

/// One timed arrival of the interleaved multi-tenant stream.
#[derive(Clone, Debug, PartialEq)]
pub struct TimedArrival {
    /// Arrival time, seconds since the start of the run.
    pub at: f64,
    pub tenant: usize,
    pub request: Request,
}

/// Poisson arrival process layered on `MultiTenantGenerator`:
/// exponential inter-arrival gaps at `rate` requests/second from a
/// seeded RNG, so arrival times are non-decreasing and fully
/// deterministic per seed.  `rate = None` drops the whole stream at
/// `t = 0` — the paper's batch protocol (and the shape the 1-replica
/// cluster reduction pins against the classic serving path).
pub fn timed_arrivals(
    tenants: &[TenantSpec],
    total_requests: usize,
    rate: Option<f64>,
    seed: u64,
) -> anyhow::Result<Vec<TimedArrival>> {
    if let Some(r) = rate {
        if r.is_nan() || r <= 0.0 {
            anyhow::bail!("arrival rate must be positive, got {r}");
        }
    }
    let mut gen = MultiTenantGenerator::new(tenants, total_requests, seed);
    // Independent clock stream: timing draws must not perturb the
    // request interleaving (same stream as the untimed generator).
    let mut clock_rng = Rng::new(seed.wrapping_mul(0x9E6D_62D0_6F6A_9A21).wrapping_add(3));
    let mut now = 0.0f64;
    let mut out = Vec::with_capacity(gen.total());
    while let Some(tr) = gen.next_request() {
        if let Some(rate) = rate {
            now += clock_rng.next_exp(rate);
        }
        out.push(TimedArrival { at: now, tenant: tr.tenant, request: tr.request });
    }
    Ok(out)
}

/// Bursty Poisson arrivals: the stream is cut into `phases` equal runs
/// of arrivals whose rate alternates between `base_rate` (calm) and
/// `base_rate * burst_factor` (burst), starting calm — a square-wave
/// load profile (burst then lull) that exercises admission pressure
/// and replica autoscaling.  Layered on the same request stream and
/// the same clock RNG as [`timed_arrivals`], so `burst_factor = 1.0`
/// reproduces the plain Poisson stream **bit-identically** and the
/// (tenant, request) interleaving never depends on the timing draws.
pub fn timed_arrivals_bursty(
    tenants: &[TenantSpec],
    total_requests: usize,
    base_rate: f64,
    burst_factor: f64,
    phases: usize,
    seed: u64,
) -> anyhow::Result<Vec<TimedArrival>> {
    if base_rate.is_nan() || base_rate <= 0.0 {
        anyhow::bail!("arrival rate must be positive, got {base_rate}");
    }
    if !burst_factor.is_finite() || burst_factor < 1.0 {
        anyhow::bail!("burst factor must be >= 1, got {burst_factor}");
    }
    if phases == 0 {
        anyhow::bail!("burst profile needs at least one phase");
    }
    let mut gen = MultiTenantGenerator::new(tenants, total_requests, seed);
    // Same clock-stream salt as `timed_arrivals`: the exponential draws
    // are identical, only the rate scaling differs per phase.
    let mut clock_rng = Rng::new(seed.wrapping_mul(0x9E6D_62D0_6F6A_9A21).wrapping_add(3));
    let phase_len = gen.total().div_ceil(phases).max(1);
    let mut now = 0.0f64;
    let mut out = Vec::with_capacity(gen.total());
    let mut i = 0usize;
    while let Some(tr) = gen.next_request() {
        let rate = if (i / phase_len) % 2 == 1 { base_rate * burst_factor } else { base_rate };
        now += clock_rng.next_exp(rate);
        out.push(TimedArrival { at: now, tenant: tr.tenant, request: tr.request });
        i += 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_normalized_and_skewed() {
        for n in [1usize, 3, 8] {
            for skew in [0.0, 1.0, 2.0] {
                let ts = tenant_set(n, skew);
                let total: f64 = ts.iter().map(|t| t.share).sum();
                assert!((total - 1.0).abs() < 1e-12, "n={n} skew={skew}");
            }
        }
        let uniform = tenant_set(4, 0.0);
        assert!((uniform[0].share - 0.25).abs() < 1e-12);
        let skewed = tenant_set(4, 2.0);
        assert!(skewed[0].share > 0.5, "head tenant dominates at skew 2");
        assert!(skewed[3].share < uniform[3].share);
    }

    #[test]
    fn prompts_cycle_table2_and_differ_per_tenant() {
        let ts = tenant_set(5, 1.0);
        assert_eq!(ts[0].prompt_tokens, 26472);
        assert_eq!(ts[1].prompt_tokens, 7069);
        assert_eq!(ts[2].prompt_tokens, 4759);
        assert_eq!(ts[3].prompt_tokens, 26472);
        let a = ts[0].prompt_token_ids(256);
        let d = ts[3].prompt_token_ids(256);
        assert_eq!(a.len(), d.len());
        assert_ne!(&a[..64], &d[..64], "same length, distinct content");
        assert_eq!(a, ts[0].prompt_token_ids(256), "deterministic");
    }

    #[test]
    fn generator_deterministic_and_complete() {
        let ts = tenant_set(3, 1.0);
        let mut a = MultiTenantGenerator::new(&ts, 60, 7);
        let mut b = MultiTenantGenerator::new(&ts, 60, 7);
        let mut n = 0;
        let mut seen = vec![0usize; 3];
        while let Some(ra) = a.next_request() {
            assert_eq!(Some(&ra), b.next_request().as_ref());
            seen[ra.tenant] += 1;
            n += 1;
        }
        assert!(b.is_exhausted());
        assert_eq!(n, a.total());
        assert!(seen.iter().all(|&c| c > 0), "every tenant sends traffic: {seen:?}");
        // Shares shape the counts: head tenant sends the most.
        assert!(seen[0] > seen[2], "{seen:?}");
    }

    #[test]
    fn every_tenant_floors_at_one_request() {
        let ts = tenant_set(8, 3.0); // tail shares are tiny
        let g = MultiTenantGenerator::new(&ts, 10, 1);
        assert!(g.total() >= 8, "floor of 1 per tenant");
    }

    #[test]
    fn timed_arrivals_monotone_deterministic_and_same_stream() {
        let ts = tenant_set(3, 1.0);
        let a = timed_arrivals(&ts, 60, Some(10.0), 7).unwrap();
        let b = timed_arrivals(&ts, 60, Some(10.0), 7).unwrap();
        assert_eq!(a, b, "deterministic per seed");
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at), "non-decreasing times");
        assert!(a[0].at > 0.0, "first gap drawn from the process");
        // Timing is layered on top: the (tenant, request) stream equals
        // the untimed generator's exactly.
        let mut gen = MultiTenantGenerator::new(&ts, 60, 7);
        for ta in &a {
            let tr = gen.next_request().unwrap();
            assert_eq!((ta.tenant, &ta.request), (tr.tenant, &tr.request));
        }
        assert!(gen.is_exhausted());
    }

    #[test]
    fn timed_arrivals_batch_mode_all_at_zero() {
        let ts = tenant_set(2, 0.0);
        let a = timed_arrivals(&ts, 20, None, 3).unwrap();
        assert!(!a.is_empty());
        assert!(a.iter().all(|t| t.at == 0.0));
        assert!(timed_arrivals(&ts, 20, Some(0.0), 3).is_err(), "bad rate is an error");
    }

    #[test]
    fn timed_arrivals_mean_gap_tracks_rate() {
        let ts = tenant_set(2, 1.0);
        let rate = 50.0;
        let a = timed_arrivals(&ts, 2000, Some(rate), 11).unwrap();
        let mean_gap = a.last().unwrap().at / a.len() as f64;
        assert!(
            (mean_gap - 1.0 / rate).abs() / (1.0 / rate) < 0.15,
            "mean gap {mean_gap} vs expected {}",
            1.0 / rate
        );
    }

    /// The bursty profile layers on the same streams: factor 1 is
    /// bit-identical to the plain Poisson process, bursts only compress
    /// the odd phases' gaps, and the request interleaving is untouched.
    #[test]
    fn bursty_arrivals_layer_on_the_same_streams() {
        let ts = tenant_set(3, 1.0);
        let plain = timed_arrivals(&ts, 64, Some(25.0), 9).unwrap();
        let unit = timed_arrivals_bursty(&ts, 64, 25.0, 1.0, 6, 9).unwrap();
        assert_eq!(plain.len(), unit.len());
        for (a, b) in plain.iter().zip(&unit) {
            assert_eq!(a.at.to_bits(), b.at.to_bits(), "factor 1 is the plain process");
            assert_eq!((a.tenant, &a.request), (b.tenant, &b.request));
        }

        let bursty = timed_arrivals_bursty(&ts, 64, 25.0, 50.0, 6, 9).unwrap();
        assert_eq!(bursty.len(), plain.len());
        assert!(bursty.windows(2).all(|w| w[0].at <= w[1].at), "non-decreasing");
        for (a, b) in plain.iter().zip(&bursty) {
            assert_eq!((a.tenant, &a.request), (b.tenant, &b.request), "same stream");
        }
        // Burst phases compress: the bursty stream finishes earlier.
        assert!(
            bursty.last().unwrap().at < plain.last().unwrap().at,
            "bursts compress the schedule"
        );
        // Mean gap inside a burst phase is ~factor-x shorter than in a
        // calm phase.
        let n = bursty.len();
        let phase = n.div_ceil(6).max(1);
        let gap = |w: &[TimedArrival]| {
            (w.last().unwrap().at - w[0].at) / (w.len() - 1) as f64
        };
        let calm = gap(&bursty[..phase]);
        let burst = gap(&bursty[phase..2 * phase]);
        assert!(calm > 5.0 * burst, "calm {calm} vs burst {burst}");
    }

    #[test]
    fn bursty_arrivals_reject_bad_profiles() {
        let ts = tenant_set(2, 0.0);
        assert!(timed_arrivals_bursty(&ts, 16, 0.0, 2.0, 4, 1).is_err());
        assert!(timed_arrivals_bursty(&ts, 16, 10.0, 0.5, 4, 1).is_err());
        assert!(timed_arrivals_bursty(&ts, 16, 10.0, f64::INFINITY, 4, 1).is_err());
        assert!(timed_arrivals_bursty(&ts, 16, 10.0, 2.0, 0, 1).is_err());
    }

    #[test]
    fn different_seeds_differ() {
        let ts = tenant_set(3, 1.0);
        let mut a = MultiTenantGenerator::new(&ts, 60, 1);
        let mut b = MultiTenantGenerator::new(&ts, 60, 2);
        let differs = (0..40).any(|_| a.next_request() != b.next_request());
        assert!(differs);
    }
}
