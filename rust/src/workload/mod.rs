//! Workload modeling: the paper's benchmark datasets (length
//! distributions), system prompts (Table 2) and request generation.

pub mod datasets;
pub mod generator;
pub mod prompts;

pub use datasets::{all_datasets, Dataset, Example};
pub use generator::{Request, RequestGenerator};
pub use prompts::{all_prompts, SystemPrompt, PROMPT_A, PROMPT_B, PROMPT_C};
