//! Workload modeling: the paper's benchmark datasets (length
//! distributions), system prompts (Table 2), request generation, and
//! the multi-tenant (per-prefix-group) traffic generator.

pub mod datasets;
pub mod generator;
pub mod prompts;
pub mod tenants;

pub use datasets::{all_datasets, Dataset, Example};
pub use generator::{Request, RequestGenerator};
pub use prompts::{all_prompts, SystemPrompt, PROMPT_A, PROMPT_B, PROMPT_C};
pub use tenants::{
    tenant_set, timed_arrivals, MultiTenantGenerator, TenantRequest, TenantSpec, TimedArrival,
};
