//! Statistical models of the paper's benchmark datasets.
//!
//! Substitution (DESIGN.md §6): attention throughput depends only on the
//! question/answer token-*length* distributions and the arrival pattern,
//! not on token content, so each dataset is modeled by its published
//! length statistics.  Lengths are sampled log-normally (token lengths
//! of NL corpora are approximately log-normal) clipped to observed
//! ranges.

use crate::util::rng::Rng;

/// A dataset's length model.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: &'static str,
    /// Mean/σ of ln(question tokens).
    q_mu: f64,
    q_sigma: f64,
    q_range: (usize, usize),
    /// Mean/σ of ln(answer tokens) — generation length until EOS.
    a_mu: f64,
    a_sigma: f64,
    a_range: (usize, usize),
    /// Number of examples in the benchmark split.
    pub size: usize,
}

/// MMLU (Hendrycks et al., 2021): multiple-choice; short-ish questions
/// (stem + 4 options, ~100 tokens median), short answers.
pub fn mmlu() -> Dataset {
    Dataset {
        name: "mmlu",
        q_mu: (100.0f64).ln(),
        q_sigma: 0.55,
        q_range: (16, 1024),
        a_mu: (24.0f64).ln(),
        a_sigma: 0.6,
        a_range: (2, 256),
        size: 14042,
    }
}

/// GSM8K (Cobbe et al., 2021): grade-school math; short questions,
/// longer chain-of-thought answers (~130 tokens median).
pub fn gsm8k() -> Dataset {
    Dataset {
        name: "gsm8k",
        q_mu: (60.0f64).ln(),
        q_sigma: 0.4,
        q_range: (16, 512),
        a_mu: (130.0f64).ln(),
        a_sigma: 0.5,
        a_range: (16, 512),
        size: 1319,
    }
}

/// SimpleQA (Wei et al., 2024): short factual questions, terse answers.
pub fn simpleqa() -> Dataset {
    Dataset {
        name: "simpleqa",
        q_mu: (20.0f64).ln(),
        q_sigma: 0.35,
        q_range: (6, 128),
        a_mu: (12.0f64).ln(),
        a_sigma: 0.5,
        a_range: (1, 128),
        size: 4326,
    }
}

pub fn all_datasets() -> [Dataset; 3] {
    [mmlu(), gsm8k(), simpleqa()]
}

pub fn by_name(name: &str) -> Option<Dataset> {
    match name {
        "mmlu" => Some(mmlu()),
        "gsm8k" => Some(gsm8k()),
        "simpleqa" => Some(simpleqa()),
        _ => None,
    }
}

/// One sampled benchmark example.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Example {
    pub question_tokens: usize,
    pub answer_tokens: usize,
}

impl Dataset {
    fn clip(x: f64, (lo, hi): (usize, usize)) -> usize {
        (x.round() as i64).clamp(lo as i64, hi as i64) as usize
    }

    pub fn sample(&self, rng: &mut Rng) -> Example {
        Example {
            question_tokens: Self::clip(rng.next_lognormal(self.q_mu, self.q_sigma), self.q_range),
            answer_tokens: Self::clip(rng.next_lognormal(self.a_mu, self.a_sigma), self.a_range),
        }
    }

    /// Sample the whole benchmark split (the paper's experiments run
    /// until the dataset is exhausted).
    pub fn sample_split(&self, seed: u64) -> Vec<Example> {
        let mut rng = Rng::new(seed ^ self.name.len() as u64);
        (0..self.size).map(|_| self.sample(&mut rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn medians_near_model_targets() {
        let mut rng = Rng::new(7);
        let ds = mmlu();
        let mut qs: Vec<usize> = (0..20_000).map(|_| ds.sample(&mut rng).question_tokens).collect();
        qs.sort();
        let median = qs[qs.len() / 2] as f64;
        assert!((median - 100.0).abs() / 100.0 < 0.1, "median {median}");
    }

    #[test]
    fn ranges_respected() {
        let mut rng = Rng::new(3);
        for ds in all_datasets() {
            for _ in 0..5_000 {
                let e = ds.sample(&mut rng);
                assert!(e.question_tokens >= ds.q_range.0 && e.question_tokens <= ds.q_range.1);
                assert!(e.answer_tokens >= ds.a_range.0 && e.answer_tokens <= ds.a_range.1);
            }
        }
    }

    #[test]
    fn split_is_deterministic_and_full_size() {
        let ds = gsm8k();
        let a = ds.sample_split(1);
        let b = ds.sample_split(1);
        assert_eq!(a, b);
        assert_eq!(a.len(), ds.size);
        assert_ne!(a, ds.sample_split(2));
    }

    #[test]
    fn gsm8k_answers_longer_than_simpleqa() {
        let g: f64 = gsm8k()
            .sample_split(5)
            .iter()
            .map(|e| e.answer_tokens as f64)
            .sum::<f64>()
            / gsm8k().size as f64;
        let s: f64 = simpleqa()
            .sample_split(5)
            .iter()
            .map(|e| e.answer_tokens as f64)
            .sum::<f64>()
            / simpleqa().size as f64;
        assert!(g > 3.0 * s, "gsm8k {g} vs simpleqa {s}");
    }
}
