//! Request generation: turns (dataset x system prompt) into a stream of
//! serving requests, reproducing the paper's experimental protocol —
//! "each experiment starts by randomly sampling questions from a
//! dataset and forming a batch of queries ... completed queries are
//! replaced with new questions ... until the entire dataset is
//! processed" (continuous batching).

use std::collections::VecDeque;

use crate::util::rng::Rng;

use super::datasets::Dataset;
use super::prompts::SystemPrompt;

/// One inference request (lengths only; token ids are synthesized by
/// the engine layer when actually executing).
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Non-shared prompt (the dataset question), tokens.
    pub prompt_tokens: usize,
    /// Generation budget until EOS, tokens.
    pub max_new_tokens: usize,
}

/// A finite request stream over one dataset split, shuffled.
#[derive(Debug)]
pub struct RequestGenerator {
    queue: VecDeque<Request>,
    pub prompt: SystemPrompt,
    total: usize,
}

impl RequestGenerator {
    pub fn new(dataset: &Dataset, prompt: SystemPrompt, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut examples = dataset.sample_split(seed);
        rng.shuffle(&mut examples);
        let queue: VecDeque<Request> = examples
            .iter()
            .enumerate()
            .map(|(i, e)| Request {
                id: i as u64,
                prompt_tokens: e.question_tokens,
                max_new_tokens: e.answer_tokens,
            })
            .collect();
        let total = queue.len();
        RequestGenerator { queue, prompt, total }
    }

    /// Cap the stream length (for fast tests / CPU e2e runs).
    pub fn take(mut self, n: usize) -> Self {
        self.queue.truncate(n);
        self.total = self.queue.len();
        self
    }

    pub fn next_request(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    pub fn remaining(&self) -> usize {
        self.queue.len()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn is_exhausted(&self) -> bool {
        self.queue.is_empty()
    }

    /// Total tokens the full stream will generate (for conservation
    /// checks in the simulator).
    pub fn total_new_tokens(&self) -> usize {
        self.queue.iter().map(|r| r.max_new_tokens).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::datasets::simpleqa;
    use crate::workload::prompts::PROMPT_C;

    #[test]
    fn generator_covers_whole_split() {
        let ds = simpleqa();
        let mut g = RequestGenerator::new(&ds, PROMPT_C, 42);
        assert_eq!(g.total(), ds.size);
        let mut n = 0;
        while g.next_request().is_some() {
            n += 1;
        }
        assert_eq!(n, ds.size);
        assert!(g.is_exhausted());
    }

    #[test]
    fn deterministic_by_seed() {
        let ds = simpleqa();
        let mut a = RequestGenerator::new(&ds, PROMPT_C, 1);
        let mut b = RequestGenerator::new(&ds, PROMPT_C, 1);
        for _ in 0..50 {
            assert_eq!(a.next_request(), b.next_request());
        }
        let mut c = RequestGenerator::new(&ds, PROMPT_C, 2);
        let different = (0..50).any(|_| a.next_request() != c.next_request());
        assert!(different);
    }

    #[test]
    fn take_caps_stream() {
        let g = RequestGenerator::new(&simpleqa(), PROMPT_C, 1).take(10);
        assert_eq!(g.total(), 10);
        assert_eq!(g.remaining(), 10);
    }
}
