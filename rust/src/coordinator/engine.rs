//! The execution-engine abstraction the coordinator drives.
//!
//! Two implementations exist:
//! * `runtime::TinyModelEngine` — real execution of the AOT-compiled
//!   tiny transformer on the PJRT CPU client;
//! * `simulator::SimEngine` — cost-model timing at paper scale
//!   (DeepSeek-v3 / Kimi K2 on NPU/GPU hardware specs).
//!
//! A decode iteration is **grouped by shared prefix**: every sequence
//! belongs to exactly one prefix group (its tenant's system prompt),
//! and the paper's naive-stage amortization argument (§3) applies *per
//! group* — so the batch carries a per-group partition and a per-group
//! kernel decision instead of one global `shared_len`/kernel pair.  A
//! single-tenant batch has exactly one group and reduces to the old
//! formulation bit-for-bit.

use anyhow::Result;

use crate::config::KernelKind;
use crate::kvcache::{PrefixId, SeqId};
use crate::metrics::BreakdownTimers;

/// One prefix group's slice of a decode batch.  `start..start+len`
/// indexes `DecodeBatch::seqs` / `context_lens`; members of a group are
/// contiguous and keep their admission order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchGroup {
    /// The shared prefix (tenant system prompt) this group attends to.
    pub prefix: PrefixId,
    /// Length of that shared prefix, tokens.
    pub shared_len: usize,
    /// Kernel selected for this group (the fall-back rule is evaluated
    /// per group against the *group's* occupancy, not the batch's).
    pub kernel: KernelKind,
    /// First member index into the batch arrays.
    pub start: usize,
    /// Member count (group occupancy).
    pub len: usize,
}

/// One decode iteration over the running set, partitioned into prefix
/// groups.  (`Default` is the coordinator's empty recycled scratch,
/// never a batch an engine sees.)
#[derive(Clone, Debug, Default)]
pub struct DecodeBatch {
    /// All sequences this iteration, grouped-contiguous.
    pub seqs: Vec<SeqId>,
    /// Per-sequence non-shared context length *before* this step,
    /// parallel to `seqs`.
    pub context_lens: Vec<usize>,
    /// The group partition.  Non-empty; group slices tile
    /// `0..seqs.len()` exactly, in scheduler order.
    pub groups: Vec<BatchGroup>,
}

impl DecodeBatch {
    /// A single-group batch — the classic single-shared-prefix shape
    /// every pre-tenancy call site used.
    pub fn single(
        kernel: KernelKind,
        shared_len: usize,
        seqs: Vec<SeqId>,
        context_lens: Vec<usize>,
    ) -> Self {
        let len = seqs.len();
        DecodeBatch {
            seqs,
            context_lens,
            groups: vec![BatchGroup { prefix: 0, shared_len, kernel, start: 0, len }],
        }
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// The batch's kernel when every group agrees (always true for
    /// single-prefix configs); `None` for a mixed iteration.
    pub fn uniform_kernel(&self) -> Option<KernelKind> {
        let first = self.groups.first()?.kernel;
        self.groups.iter().all(|g| g.kernel == first).then_some(first)
    }

    /// A group's member sequence ids.
    pub fn group_seqs(&self, g: &BatchGroup) -> &[SeqId] {
        &self.seqs[g.start..g.start + g.len]
    }

    /// A group's member context lengths.
    pub fn group_lens(&self, g: &BatchGroup) -> &[usize] {
        &self.context_lens[g.start..g.start + g.len]
    }
}

/// One newly-admitted sequence to prefill: its non-shared prompt plus
/// the shared-prefix length its group attends to (prefill cost models
/// the question attending to prefix + itself).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefillRequest {
    pub seq: SeqId,
    /// Non-shared context tokens to prefill (prompt, plus regenerated
    /// tokens for preempted requeues).
    pub context_len: usize,
    /// Shared-prefix length visible to this sequence's group.
    pub shared_len: usize,
}

#[derive(Clone, Debug, Default)]
pub struct IterationOutcome {
    /// Engine-reported execution time (wall seconds for real engines,
    /// modeled seconds for the simulator).
    pub seconds: f64,
    pub breakdown: BreakdownTimers,
}

pub trait Engine {
    /// Prefill + cache a shared prefix; for TyphoonMLA this includes the
    /// uncompressed expansion.  Called once per registered prefix group.
    /// Returns modeled/measured seconds.
    fn prepare_shared(
        &mut self,
        prefix: PrefixId,
        tokens: &[u32],
        kernel: KernelKind,
    ) -> Result<f64>;

    /// Batched prefill of newly-admitted requests (non-shared prompts).
    fn prefill_requests(&mut self, seqs: &[PrefillRequest]) -> Result<f64>;

    /// One decode iteration; every sequence in the batch emits one token.
    fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome>;

    /// Free engine-side state of a finished/cancelled sequence.
    fn release(&mut self, seq: SeqId);

    /// Max sequences the engine can decode per iteration (artifact
    /// bucket size for the PJRT engine; unbounded for the simulator).
    fn max_batch(&self) -> usize {
        usize::MAX
    }
}

/// A trivial engine with fixed step times.  Used by scheduler benches
/// and server tests where execution content doesn't matter.
#[derive(Clone, Debug)]
pub struct NullEngine {
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
}

impl Default for NullEngine {
    fn default() -> Self {
        NullEngine { prefill_seconds: 0.0, decode_seconds: 0.0 }
    }
}

impl Engine for NullEngine {
    fn prepare_shared(
        &mut self,
        _prefix: PrefixId,
        _tokens: &[u32],
        _kernel: KernelKind,
    ) -> Result<f64> {
        Ok(self.prefill_seconds)
    }

    fn prefill_requests(&mut self, _seqs: &[PrefillRequest]) -> Result<f64> {
        Ok(self.prefill_seconds)
    }

    fn decode(&mut self, _batch: &DecodeBatch) -> Result<IterationOutcome> {
        Ok(IterationOutcome {
            seconds: self.decode_seconds,
            breakdown: BreakdownTimers::default(),
        })
    }

    fn release(&mut self, _seq: SeqId) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_group_tiles_whole_batch() {
        let b = DecodeBatch::single(KernelKind::Typhoon, 4096, vec![3, 1, 2], vec![5, 6, 7]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.groups.len(), 1);
        assert_eq!(b.uniform_kernel(), Some(KernelKind::Typhoon));
        assert_eq!(b.group_seqs(&b.groups[0]), &[3, 1, 2]);
        assert_eq!(b.group_lens(&b.groups[0]), &[5, 6, 7]);
    }

    #[test]
    fn mixed_kernels_are_not_uniform() {
        let b = DecodeBatch {
            seqs: vec![0, 1, 2],
            context_lens: vec![1, 2, 3],
            groups: vec![
                BatchGroup {
                    prefix: 0,
                    shared_len: 4096,
                    kernel: KernelKind::Typhoon,
                    start: 0,
                    len: 2,
                },
                BatchGroup {
                    prefix: 1,
                    shared_len: 128,
                    kernel: KernelKind::Absorb,
                    start: 2,
                    len: 1,
                },
            ],
        };
        assert_eq!(b.uniform_kernel(), None);
        assert_eq!(b.group_seqs(&b.groups[1]), &[2]);
    }
}
