//! The execution-engine abstraction the coordinator drives.
//!
//! Two implementations exist:
//! * `runtime::TinyModelEngine` — real execution of the AOT-compiled
//!   tiny transformer on the PJRT CPU client;
//! * `simulator::SimEngine` — cost-model timing at paper scale
//!   (DeepSeek-v3 / Kimi K2 on NPU/GPU hardware specs).

use anyhow::Result;

use crate::config::KernelKind;
use crate::kvcache::{PrefixId, SeqId};
use crate::metrics::BreakdownTimers;

/// One decode iteration over the running set.
#[derive(Clone, Debug)]
pub struct DecodeBatch {
    pub seqs: Vec<SeqId>,
    pub kernel: KernelKind,
    /// Shared prefix length visible to every sequence in the batch.
    pub shared_len: usize,
    /// Per-sequence non-shared context length *before* this step.
    pub context_lens: Vec<usize>,
}

#[derive(Clone, Debug, Default)]
pub struct IterationOutcome {
    /// Engine-reported execution time (wall seconds for real engines,
    /// modeled seconds for the simulator).
    pub seconds: f64,
    pub breakdown: BreakdownTimers,
}

pub trait Engine {
    /// Prefill + cache a shared prefix; for TyphoonMLA this includes the
    /// uncompressed expansion.  Returns modeled/measured seconds.
    fn prepare_shared(
        &mut self,
        prefix: PrefixId,
        tokens: &[u32],
        kernel: KernelKind,
    ) -> Result<f64>;

    /// Batched prefill of newly-admitted requests (non-shared prompts).
    fn prefill_requests(&mut self, seqs: &[(SeqId, usize)]) -> Result<f64>;

    /// One decode iteration; every sequence in the batch emits one token.
    fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome>;

    /// Free engine-side state of a finished/cancelled sequence.
    fn release(&mut self, seq: SeqId);

    /// Max sequences the engine can decode per iteration (artifact
    /// bucket size for the PJRT engine; unbounded for the simulator).
    fn max_batch(&self) -> usize {
        usize::MAX
    }
}

/// A trivial engine with fixed step times.  Used by scheduler benches
/// and server tests where execution content doesn't matter.
#[derive(Clone, Debug)]
pub struct NullEngine {
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
}

impl Default for NullEngine {
    fn default() -> Self {
        NullEngine { prefill_seconds: 0.0, decode_seconds: 0.0 }
    }
}

impl Engine for NullEngine {
    fn prepare_shared(
        &mut self,
        _prefix: PrefixId,
        _tokens: &[u32],
        _kernel: KernelKind,
    ) -> Result<f64> {
        Ok(self.prefill_seconds)
    }

    fn prefill_requests(&mut self, _seqs: &[(SeqId, usize)]) -> Result<f64> {
        Ok(self.prefill_seconds)
    }

    fn decode(&mut self, _batch: &DecodeBatch) -> Result<IterationOutcome> {
        Ok(IterationOutcome {
            seconds: self.decode_seconds,
            breakdown: BreakdownTimers::default(),
        })
    }

    fn release(&mut self, _seq: SeqId) {}
}
