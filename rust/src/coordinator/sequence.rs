//! Per-request sequence lifecycle.

use crate::kvcache::{PrefixId, SeqId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqState {
    /// Waiting for admission (KV capacity / batch slot).
    Queued,
    /// Admitted; prompt prefill pending or done, decoding tokens.
    Decoding,
    /// Hit its generation budget (or EOS).
    Finished,
    /// Dropped before completion.
    Cancelled,
}

#[derive(Clone, Debug)]
pub struct Sequence {
    pub id: SeqId,
    /// The prefix group (tenant system prompt) this sequence attends
    /// to — set at submission, immutable for the sequence's lifetime.
    pub prefix: PrefixId,
    /// Non-shared prompt length (the dataset question), tokens.
    pub prompt_tokens: usize,
    /// Generation budget.
    pub max_new_tokens: usize,
    pub generated: usize,
    pub state: SeqState,
    /// Simulated/wall time at submission and completion (seconds).
    pub submitted_at: f64,
    pub finished_at: Option<f64>,
    /// Time the first token was generated (TTFT anchor).
    pub first_token_at: Option<f64>,
}

impl Sequence {
    pub fn new(
        id: SeqId,
        prefix: PrefixId,
        prompt_tokens: usize,
        max_new_tokens: usize,
        now: f64,
    ) -> Self {
        Sequence {
            id,
            prefix,
            prompt_tokens,
            max_new_tokens: max_new_tokens.max(1),
            generated: 0,
            state: SeqState::Queued,
            submitted_at: now,
            finished_at: None,
            first_token_at: None,
        }
    }

    /// Current non-shared context length (prompt + generated so far).
    pub fn context_len(&self) -> usize {
        self.prompt_tokens + self.generated
    }

    /// The sequence's prefix group (alias of `prefix`, named for the
    /// tenancy layer).
    pub fn group(&self) -> PrefixId {
        self.prefix
    }

    /// Record one generated token; returns true when the budget is hit.
    pub fn advance(&mut self, now: f64) -> bool {
        debug_assert_eq!(self.state, SeqState::Decoding);
        self.generated += 1;
        if self.generated == 1 {
            self.first_token_at = Some(now);
        }
        if self.generated >= self.max_new_tokens {
            self.state = SeqState::Finished;
            self.finished_at = Some(now);
            true
        } else {
            false
        }
    }

    pub fn latency(&self) -> Option<f64> {
        self.finished_at.map(|t| t - self.submitted_at)
    }

    /// Time-to-first-token (None until a token was generated).
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.submitted_at)
    }

    /// Mean inter-token time after the first token; defined only for
    /// finished sequences that generated at least two tokens.
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_at, self.finished_at) {
            (Some(first), Some(end)) if self.generated >= 2 => {
                Some((end - first) / (self.generated - 1) as f64)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut s = Sequence::new(1, 0, 10, 3, 0.0);
        assert_eq!(s.state, SeqState::Queued);
        s.state = SeqState::Decoding;
        assert_eq!(s.ttft(), None, "no token yet");
        assert!(!s.advance(1.0));
        assert!(!s.advance(2.0));
        assert_eq!(s.context_len(), 12);
        assert!(s.advance(3.0));
        assert_eq!(s.state, SeqState::Finished);
        assert_eq!(s.latency(), Some(3.0));
        assert_eq!(s.ttft(), Some(1.0));
        // 3 tokens over [1.0, 3.0]: two gaps of 1.0 each.
        assert_eq!(s.tpot(), Some(1.0));
    }

    #[test]
    fn tpot_undefined_for_single_token() {
        let mut s = Sequence::new(1, 0, 4, 1, 0.5);
        s.state = SeqState::Decoding;
        assert!(s.advance(2.0));
        assert_eq!(s.ttft(), Some(1.5));
        assert_eq!(s.tpot(), None, "one token has no inter-token gap");
    }

    #[test]
    fn zero_budget_clamped_to_one() {
        let mut s = Sequence::new(1, 0, 4, 0, 0.0);
        s.state = SeqState::Decoding;
        assert!(s.advance(0.5), "at least one token is always generated");
    }
}
