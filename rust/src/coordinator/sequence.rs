//! Per-request sequence lifecycle.

use crate::kvcache::{PrefixId, SeqId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqState {
    /// Waiting for admission (KV capacity / batch slot).
    Queued,
    /// Admitted; prompt prefill pending or done, decoding tokens.
    Decoding,
    /// Hit its generation budget (or EOS).
    Finished,
    /// Dropped before completion.
    Cancelled,
}

#[derive(Clone, Debug)]
pub struct Sequence {
    pub id: SeqId,
    /// The prefix group (tenant system prompt) this sequence attends
    /// to — set at submission, immutable for the sequence's lifetime.
    pub prefix: PrefixId,
    /// Non-shared prompt length (the dataset question), tokens.
    pub prompt_tokens: usize,
    /// Generation budget.
    pub max_new_tokens: usize,
    pub generated: usize,
    pub state: SeqState,
    /// Simulated/wall time at submission and completion (seconds).
    pub submitted_at: f64,
    pub finished_at: Option<f64>,
}

impl Sequence {
    pub fn new(
        id: SeqId,
        prefix: PrefixId,
        prompt_tokens: usize,
        max_new_tokens: usize,
        now: f64,
    ) -> Self {
        Sequence {
            id,
            prefix,
            prompt_tokens,
            max_new_tokens: max_new_tokens.max(1),
            generated: 0,
            state: SeqState::Queued,
            submitted_at: now,
            finished_at: None,
        }
    }

    /// Current non-shared context length (prompt + generated so far).
    pub fn context_len(&self) -> usize {
        self.prompt_tokens + self.generated
    }

    /// The sequence's prefix group (alias of `prefix`, named for the
    /// tenancy layer).
    pub fn group(&self) -> PrefixId {
        self.prefix
    }

    /// Record one generated token; returns true when the budget is hit.
    pub fn advance(&mut self, now: f64) -> bool {
        debug_assert_eq!(self.state, SeqState::Decoding);
        self.generated += 1;
        if self.generated >= self.max_new_tokens {
            self.state = SeqState::Finished;
            self.finished_at = Some(now);
            true
        } else {
            false
        }
    }

    pub fn latency(&self) -> Option<f64> {
        self.finished_at.map(|t| t - self.submitted_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut s = Sequence::new(1, 0, 10, 3, 0.0);
        assert_eq!(s.state, SeqState::Queued);
        s.state = SeqState::Decoding;
        assert!(!s.advance(1.0));
        assert!(!s.advance(2.0));
        assert_eq!(s.context_len(), 12);
        assert!(s.advance(3.0));
        assert_eq!(s.state, SeqState::Finished);
        assert_eq!(s.latency(), Some(3.0));
    }

    #[test]
    fn zero_budget_clamped_to_one() {
        let mut s = Sequence::new(1, 0, 4, 0, 0.0);
        s.state = SeqState::Decoding;
        assert!(s.advance(0.5), "at least one token is always generated");
    }
}
