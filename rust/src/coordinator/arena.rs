//! Slab/arena storage for in-flight sequences (DESIGN.md §15).
//!
//! `SeqId` is a **dense index** into a slot vector: allocation pops a
//! free slot (or grows the vector), so the per-token hot path indexes
//! instead of hashing.  A slot's lifecycle mirrors the request's:
//!
//! * **reserved** — the id is allocated at `submit` while the
//!   `Sequence` itself sits in the admission queue; the slot is `None`
//!   and *not* on the free list (`Coordinator::sequence` returns `None`
//!   for queued ids, exactly as the old `HashMap` did).
//! * **installed** — admission moves the `Sequence` into the slot.
//! * **taken** — preemption moves it back out to the queue; the id
//!   stays reserved so the requeued request keeps its identity.
//! * **freed** — retirement (or crash extraction) returns the id to
//!   the free list for reuse by a later `submit`.
//!
//! Reuse means ids are only unique among *live* requests.  Callers
//! that inspect finished sequences after the fact (the server loop's
//! per-request log) run the coordinator in *retaining* mode, where
//! finished slots are never freed — byte-identical to the historical
//! always-growing map.  The cluster simulator switches retention off so
//! a million-request cell runs in O(max outstanding) memory.

use super::sequence::Sequence;
use crate::kvcache::SeqId;

#[derive(Debug, Default)]
pub struct SeqArena {
    slots: Vec<Option<Sequence>>,
    free: Vec<SeqId>,
    /// Slots currently holding a `Sequence` (installed, not reserved).
    live: usize,
    /// High-water mark of reserved+installed slots.
    peak: usize,
}

impl SeqArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate an id in the **reserved** state (slot empty, off the
    /// free list).
    pub fn reserve(&mut self) -> SeqId {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.slots.push(None);
                (self.slots.len() - 1) as SeqId
            }
        };
        self.peak = self.peak.max(self.occupied());
        id
    }

    /// Install a sequence into its reserved slot (admission).
    pub fn install(&mut self, seq: Sequence) {
        let slot = &mut self.slots[seq.id as usize];
        debug_assert!(slot.is_none(), "slot {} double-installed", seq.id);
        *slot = Some(seq);
        self.live += 1;
    }

    /// Move a sequence back out of its slot (preemption); the id stays
    /// reserved.
    pub fn take(&mut self, id: SeqId) -> Option<Sequence> {
        let seq = self.slots.get_mut(id as usize)?.take();
        if seq.is_some() {
            self.live -= 1;
        }
        seq
    }

    /// Return a **reserved** (empty) slot's id to the free list — a
    /// queued request torn down before admission.
    pub fn free_reserved(&mut self, id: SeqId) {
        debug_assert!(self.slots[id as usize].is_none());
        debug_assert!(!self.free.contains(&id), "double free of reserved id {id}");
        self.free.push(id);
    }

    /// Drop an installed sequence and recycle its id (retirement in
    /// non-retaining mode, or crash extraction).
    pub fn free(&mut self, id: SeqId) {
        if self.slots[id as usize].take().is_some() {
            self.live -= 1;
        }
        self.free.push(id);
    }

    pub fn get(&self, id: SeqId) -> Option<&Sequence> {
        self.slots.get(id as usize).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, id: SeqId) -> Option<&mut Sequence> {
        self.slots.get_mut(id as usize).and_then(|s| s.as_mut())
    }

    /// Installed sequences.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Reserved + installed slots right now.
    pub fn occupied(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// High-water mark of `occupied()` over the arena's lifetime.
    pub fn peak(&self) -> usize {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::Sequence;

    fn seq(id: SeqId) -> Sequence {
        Sequence::new(id, 0, 4, 2, 0.0)
    }

    #[test]
    fn reserve_install_free_reuses_ids() {
        let mut a = SeqArena::new();
        let i0 = a.reserve();
        let i1 = a.reserve();
        assert_ne!(i0, i1);
        assert_eq!(a.occupied(), 2);
        assert_eq!(a.live(), 0, "reserved ids hold no sequence");
        a.install(seq(i0));
        assert_eq!(a.live(), 1);
        assert!(a.get(i0).is_some());
        assert!(a.get(i1).is_none(), "reserved-but-queued id reads as absent");
        a.free(i0);
        a.free_reserved(i1);
        assert_eq!(a.occupied(), 0);
        // Freed ids come back (LIFO) before the vector grows.
        let r = a.reserve();
        assert!(r == i0 || r == i1);
        assert_eq!(a.occupied(), 1);
    }

    #[test]
    fn take_keeps_id_reserved() {
        let mut a = SeqArena::new();
        let id = a.reserve();
        a.install(seq(id));
        let s = a.take(id).expect("installed");
        assert_eq!(s.id, id);
        assert_eq!(a.live(), 0);
        assert_eq!(a.occupied(), 1, "preempted id stays reserved");
        // Re-admission reinstalls into the same slot.
        a.install(s);
        assert_eq!(a.live(), 1);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut a = SeqArena::new();
        let ids: Vec<_> = (0..5).map(|_| a.reserve()).collect();
        assert_eq!(a.peak(), 5);
        for &id in &ids {
            a.free_reserved(id);
        }
        let _ = a.reserve();
        assert_eq!(a.peak(), 5, "peak survives the drain");
        assert_eq!(a.occupied(), 1);
    }
}
