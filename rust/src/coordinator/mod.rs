//! The L3 coordinator: continuous batching over a paged, prefix-shared
//! KV-cache with TyphoonMLA's kernel-selection policy.
//!
//! This is the Orca/vLLM-style serving loop the paper's experiments
//! assume: a fixed-size decode batch where completed requests are
//! replaced by new ones sampled from the dataset each iteration.

pub mod engine;
pub mod policy;
pub mod running;
pub mod sequence;

use std::collections::{HashMap, VecDeque};

use anyhow::{anyhow, Result};

use crate::config::{KernelKind, ServingConfig};
use crate::kvcache::{KvCacheManager, PrefixId, SeqId};
use crate::metrics::{Clock, Metrics};
use crate::workload::Request;

pub use engine::{DecodeBatch, Engine, IterationOutcome};
pub use policy::KernelPolicy;
pub use running::RunningSet;
pub use sequence::{SeqState, Sequence};

pub struct Coordinator<E: Engine> {
    cfg: ServingConfig,
    policy: KernelPolicy,
    pub kv: KvCacheManager,
    pub engine: E,
    queue: VecDeque<Sequence>,
    running: RunningSet,
    seqs: HashMap<SeqId, Sequence>,
    pub metrics: Metrics,
    shared_prefix: Option<(PrefixId, usize)>,
    recently_finished: Vec<SeqId>,
    next_seq: SeqId,
    /// Canonical run clock: accumulated engine-reported seconds.
    now: f64,
}

impl<E: Engine> Coordinator<E> {
    pub fn new(
        cfg: ServingConfig,
        policy: KernelPolicy,
        kv: KvCacheManager,
        engine: E,
    ) -> Result<Self> {
        cfg.validate()?;
        Ok(Coordinator {
            cfg,
            policy,
            kv,
            engine,
            queue: VecDeque::new(),
            running: RunningSet::new(),
            seqs: HashMap::new(),
            metrics: Metrics::new(Clock::Simulated),
            shared_prefix: None,
            recently_finished: Vec::new(),
            next_seq: 0,
            now: 0.0,
        })
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Install the shared prefix (system prompt) and run its prefill.
    /// For Typhoon/Naive the uncompressed copy is materialized too.
    pub fn set_shared_prefix(&mut self, tokens: &[u32]) -> Result<PrefixId> {
        let id = self.kv.register_shared_prefix(tokens)?;
        let secs = self.engine.prepare_shared(id, tokens, self.cfg.kernel)?;
        if self.cfg.kernel == KernelKind::Typhoon || self.cfg.kernel == KernelKind::Naive {
            self.kv.expand_shared_prefix(id)?;
        }
        self.now += secs;
        self.metrics.advance_sim_time(secs);
        self.shared_prefix = Some((id, tokens.len()));
        Ok(id)
    }

    pub fn shared_len(&self) -> usize {
        self.shared_prefix.map_or(0, |(_, l)| l)
    }

    /// Enqueue a request (non-shared prompt + generation budget).
    pub fn submit(&mut self, req: &Request) -> Result<SeqId> {
        let (prefix, _) = self
            .shared_prefix
            .ok_or_else(|| anyhow!("no shared prefix installed"))?;
        let id = self.next_seq;
        self.next_seq += 1;
        let prompt = req.prompt_tokens.min(self.cfg.max_seq_len.saturating_sub(1));
        let budget = req.max_new_tokens.min(self.cfg.max_seq_len - prompt);
        let seq = Sequence::new(id, prefix, prompt, budget, self.now);
        self.queue.push_back(seq);
        Ok(id)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn sequence(&self, id: SeqId) -> Option<&Sequence> {
        self.seqs.get(&id)
    }

    fn effective_max_batch(&self) -> usize {
        self.cfg.max_batch.min(self.engine.max_batch())
    }

    /// Admit queued requests into free batch slots (continuous batching).
    fn admit(&mut self) -> Result<()> {
        let max_batch = self.effective_max_batch();
        let free = max_batch.saturating_sub(self.running.len());
        if free == 0 || free < self.cfg.admit_hysteresis.min(max_batch) {
            return Ok(());
        }
        let mut wave: Vec<(SeqId, usize)> = Vec::new();
        while self.running.len() + wave.len() < max_batch {
            let Some(front) = self.queue.front() else { break };
            // Context includes regenerated tokens for preempted requeues.
            if !self.kv.can_admit(front.context_len()) {
                break; // KV backpressure: wait for capacity
            }
            let mut seq = self.queue.pop_front().unwrap();
            self.kv.add_sequence(seq.id, seq.prefix, seq.context_len())?;
            seq.state = SeqState::Decoding;
            wave.push((seq.id, seq.context_len()));
            self.seqs.insert(seq.id, seq);
        }
        if !wave.is_empty() {
            let secs = self.engine.prefill_requests(&wave)?;
            self.now += secs;
            self.metrics.advance_sim_time(secs);
            self.metrics.prefill_calls += 1;
            self.metrics.requests_admitted += wave.len() as u64;
            for &(id, _) in &wave {
                self.running.push(id);
            }
        }
        Ok(())
    }

    /// Preempt the most-recently-admitted running sequence: release its
    /// pages and requeue it for recompute (vLLM-style recompute
    /// preemption).  Returns the victim, or None if nothing to preempt.
    fn preempt_one(&mut self, protect: SeqId) -> Result<Option<SeqId>> {
        let victim = self.running.last_except(protect);
        let Some(victim) = victim else { return Ok(None) };
        self.kv.remove_sequence(victim)?;
        self.engine.release(victim);
        self.running.remove(victim);
        let mut seq = self.seqs.remove(&victim).expect("running seq exists");
        seq.state = SeqState::Queued;
        self.queue.push_front(seq);
        self.metrics.preemptions += 1;
        Ok(Some(victim))
    }

    /// Reserve a page slot for every running sequence's next token,
    /// preempting under memory pressure.  If even a lone sequence cannot
    /// grow, it is force-finished at its current length.
    fn reserve_next_token(&mut self) -> Result<Vec<SeqId>> {
        let mut force_finished = Vec::new();
        for id in self.running.snapshot() {
            if !self.running.contains(id) {
                continue; // already preempted this round
            }
            loop {
                match self.kv.append_token(id) {
                    Ok(()) => break,
                    Err(_) => {
                        if self.preempt_one(id)?.is_none() {
                            // Nothing left to evict: out of pool for this
                            // sequence — finish it where it stands.
                            force_finished.push(id);
                            break;
                        }
                    }
                }
            }
        }
        Ok(force_finished)
    }

    /// One scheduler step: admit, decode one iteration, retire finished.
    /// Returns false when there is nothing left to do.
    pub fn step(&mut self) -> Result<bool> {
        self.admit()?;
        if self.running.is_empty() {
            return Ok(!self.queue.is_empty());
        }
        // Page reservation for this step's tokens (may preempt).
        let force_finished = self.reserve_next_token()?;
        self.running.remove_many(&force_finished);
        for id in force_finished {
            self.kv.remove_sequence(id)?;
            self.engine.release(id);
            let seq = self.seqs.get_mut(&id).unwrap();
            seq.state = SeqState::Finished;
            seq.finished_at = Some(self.now);
            self.metrics.requests_completed += 1;
            self.recently_finished.push(id);
        }
        if self.running.is_empty() {
            return Ok(!self.queue.is_empty());
        }

        let shared_len = self.shared_len();
        let kernel = self.policy.select(self.running.len(), shared_len);
        let context_lens: Vec<usize> = self
            .running
            .iter()
            .map(|id| self.seqs[&id].context_len())
            .collect();
        let batch = DecodeBatch {
            seqs: self.running.snapshot(),
            kernel,
            shared_len,
            context_lens,
        };
        let outcome = self.engine.decode(&batch)?;
        self.now += outcome.seconds;
        match kernel {
            KernelKind::Typhoon => self.metrics.typhoon_iters += 1,
            KernelKind::Absorb => self.metrics.absorb_iters += 1,
            KernelKind::Naive => self.metrics.naive_iters += 1,
        }
        self.metrics.breakdown.add(&outcome.breakdown);

        // Every running sequence produced one token (pages were
        // reserved above).
        let mut finished: Vec<SeqId> = Vec::new();
        for &id in &batch.seqs {
            let seq = self.seqs.get_mut(&id).unwrap();
            let done = seq.advance(self.now) || seq.context_len() >= self.cfg.max_seq_len;
            if done {
                seq.state = SeqState::Finished;
                seq.finished_at.get_or_insert(self.now);
                finished.push(id);
            }
        }
        self.running.remove_many(&finished);
        for id in &finished {
            self.kv.remove_sequence(*id)?;
            self.engine.release(*id);
            self.metrics.requests_completed += 1;
            if let Some(lat) = self.seqs[id].latency() {
                self.metrics.request_latency.push(lat);
            }
            self.recently_finished.push(*id);
        }
        self.metrics
            .record_iteration(outcome.seconds, batch.seqs.len(), batch.seqs.len() as u64);
        Ok(true)
    }

    /// Sequences that finished since the last call (drained).
    pub fn take_finished(&mut self) -> Vec<SeqId> {
        std::mem::take(&mut self.recently_finished)
    }

    /// Drive until queue and batch drain.  Returns total modeled seconds.
    pub fn run_to_completion(&mut self) -> Result<f64> {
        while self.step()? {}
        Ok(self.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::sim;
    use crate::metrics::BreakdownTimers;

    /// Deterministic mock: fixed prefill/decode times, records calls.
    struct MockEngine {
        decode_calls: usize,
        batch_sizes: Vec<usize>,
        kernels: Vec<KernelKind>,
    }

    impl MockEngine {
        fn new() -> Self {
            MockEngine { decode_calls: 0, batch_sizes: Vec::new(), kernels: Vec::new() }
        }
    }

    impl Engine for MockEngine {
        fn prepare_shared(
            &mut self,
            _p: PrefixId,
            _tokens: &[u32],
            _k: KernelKind,
        ) -> Result<f64> {
            Ok(0.5)
        }

        fn prefill_requests(&mut self, _seqs: &[(SeqId, usize)]) -> Result<f64> {
            Ok(0.1)
        }

        fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome> {
            self.decode_calls += 1;
            self.batch_sizes.push(batch.seqs.len());
            self.kernels.push(batch.kernel);
            Ok(IterationOutcome { seconds: 0.01, breakdown: BreakdownTimers::default() })
        }

        fn release(&mut self, _seq: SeqId) {}
    }

    fn coordinator(max_batch: usize, b_theta: usize) -> Coordinator<MockEngine> {
        let cfg = ServingConfig {
            max_batch,
            block_size: 16,
            max_seq_len: 256,
            total_blocks: 4096,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, b_theta);
        let kv = KvCacheManager::new(sim(), cfg.total_blocks, cfg.block_size);
        Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap()
    }

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request { id, prompt_tokens: prompt, max_new_tokens: gen }
    }

    #[test]
    fn runs_all_requests_to_completion() {
        let mut c = coordinator(4, 1);
        c.set_shared_prefix(&(0..64u32).collect::<Vec<_>>()).unwrap();
        for i in 0..10 {
            c.submit(&req(i, 8, 3)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 10);
        assert_eq!(c.metrics.tokens_generated, 30);
        assert_eq!(c.running(), 0);
        assert_eq!(c.queued(), 0);
        // All pages back except the shared prefix's.
        assert_eq!(c.kv.used_blocks(), 4); // 64 tokens / 16
    }

    #[test]
    fn batch_never_exceeds_max() {
        let mut c = coordinator(3, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..7 {
            c.submit(&req(i, 4, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.engine.batch_sizes.iter().all(|&b| b <= 3));
        assert!(c.engine.batch_sizes.contains(&3), "batch fills up");
    }

    #[test]
    fn continuous_batching_replaces_completed() {
        let mut c = coordinator(2, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        // One long, two short: the short ones cycle through slot 2.
        c.submit(&req(0, 4, 6)).unwrap();
        c.submit(&req(1, 4, 1)).unwrap();
        c.submit(&req(2, 4, 1)).unwrap();
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 3);
        assert_eq!(c.engine.batch_sizes[0], 2);
        assert_eq!(c.engine.batch_sizes[1], 2);
    }

    #[test]
    fn policy_fallback_at_small_batch() {
        let mut c = coordinator(8, 4);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..2 {
            c.submit(&req(i, 4, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.engine.kernels.iter().all(|&k| k == KernelKind::Absorb));
        assert_eq!(c.metrics.absorb_iters, c.metrics.decode_iterations);

        let mut c = coordinator(8, 4);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..8 {
            c.submit(&req(i, 4, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.engine.kernels.contains(&KernelKind::Typhoon));
    }

    #[test]
    fn kv_backpressure_blocks_admission() {
        // Tiny pool: shared prefix (1 page) + 3 pages => only 3 single-page
        // sequences fit at once.
        let cfg = ServingConfig {
            max_batch: 4,
            block_size: 16,
            max_seq_len: 64,
            total_blocks: 4,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, 1);
        let kv = KvCacheManager::new(sim(), 4, 16);
        let mut c = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..6 {
            c.submit(&req(i, 8, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 6, "all served eventually");
        assert!(
            c.engine.batch_sizes.iter().all(|&b| b <= 3),
            "{:?}",
            c.engine.batch_sizes
        );
    }

    #[test]
    fn submit_without_prefix_errors() {
        let mut c = coordinator(2, 1);
        assert!(c.submit(&req(0, 4, 2)).is_err());
    }

    #[test]
    fn token_conservation() {
        let mut c = coordinator(4, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        let budgets = [3usize, 1, 7, 2, 5];
        for (i, &g) in budgets.iter().enumerate() {
            c.submit(&req(i as u64, 4, g)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.tokens_generated as usize, budgets.iter().sum::<usize>());
        let by_batch: usize = c.engine.batch_sizes.iter().sum();
        assert_eq!(by_batch, budgets.iter().sum::<usize>());
    }

    #[test]
    fn preemption_under_kv_pressure() {
        // Pool: 1 prefix page + 3 pages.  Two sequences each eventually
        // need 2+ pages; one must be preempted and recomputed, and both
        // must still finish with their full budgets.
        let cfg = ServingConfig {
            max_batch: 3,
            block_size: 16,
            max_seq_len: 48,
            total_blocks: 4,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
        let kv = KvCacheManager::new(sim(), 4, 16);
        let mut c = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        c.submit(&req(0, 14, 20)).unwrap(); // grows past one page
        c.submit(&req(1, 14, 20)).unwrap();
        c.submit(&req(2, 14, 20)).unwrap();
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 3);
        assert!(c.metrics.preemptions > 0, "pressure must trigger preemption");
        assert_eq!(c.metrics.tokens_generated, 60, "budgets still met exactly");
        assert_eq!(c.kv.used_blocks(), 1, "only the prefix page remains");
    }

    #[test]
    fn max_seq_len_force_finishes() {
        let cfg = ServingConfig {
            max_batch: 1,
            block_size: 16,
            max_seq_len: 32,
            total_blocks: 64,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
        let kv = KvCacheManager::new(sim(), 64, 16);
        let mut c = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        c.submit(&req(0, 16, 100_000)).unwrap(); // budget clamped
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 1);
        let gen = c.metrics.tokens_generated as usize;
        assert!(gen <= 16, "generation stopped at context limit, got {gen}");
    }
}
