//! The L3 coordinator: continuous batching over a paged, prefix-shared
//! KV-cache with TyphoonMLA's kernel-selection policy.
//!
//! This is the Orca/vLLM-style serving loop the paper's experiments
//! assume: a fixed-size decode batch where completed requests are
//! replaced by new ones sampled from the dataset each iteration.
//!
//! **Prefix groups.**  The paper's protocol serves one global system
//! prompt; a production fleet serves many tenants, each with its own.
//! The coordinator therefore keeps a registry of shared prefixes
//! ("prefix groups"), tags every sequence with its group, and builds
//! each decode iteration as a *grouped* `DecodeBatch`: members are
//! partitioned by prefix and the Eq. 1 fall-back rule is evaluated per
//! group against the group's occupancy — a cold group falls back to
//! absorb while a hot group runs Typhoon in the same iteration.  With
//! one registered prefix this reduces to the paper's single-prompt
//! protocol bit-for-bit.

pub mod arena;
pub mod engine;
pub mod running;
pub mod sequence;

use std::collections::VecDeque;

use anyhow::{anyhow, Result};

use crate::config::{KernelKind, ServingConfig};
use crate::kvcache::{KvCacheManager, PrefixExport, PrefixId, SeqId};
use crate::metrics::{Clock, Metrics};
use crate::workload::Request;

pub use crate::policy::KernelPolicy;
pub use arena::SeqArena;
pub use engine::{BatchGroup, DecodeBatch, Engine, IterationOutcome, PrefillRequest};
pub use running::RunningSet;
pub use sequence::{SeqState, Sequence};

/// Completions kept for the windowed service-rate estimate: long
/// enough to smooth one batch worth of simultaneous retirements, short
/// enough that `service_rate` tracks the current regime instead of the
/// whole run's history.
const SERVICE_RATE_WINDOW: usize = 64;

/// Mean non-shared context length of a group's members (floor; 0 for
/// an empty slice).  Feeds the kernel registry's `GroupContext` — the
/// binary seed registry ignores it, an N-way registry prices the
/// non-shared stage with it.
fn mean_len(lens: &[usize]) -> usize {
    if lens.is_empty() {
        0
    } else {
        lens.iter().sum::<usize>() / lens.len()
    }
}

/// One sequence extracted from a failed replica for fleet-level
/// re-queueing (DESIGN.md §14): enough to restart the request from
/// scratch on a survivor.  `generated` tokens of work die with the
/// replica's pages and will be redone — the cluster books them as
/// `lost_tokens`, never silently drops the request.
#[derive(Clone, Copy, Debug)]
pub struct RequeuedWork {
    pub prefix: PrefixId,
    pub prompt_tokens: usize,
    pub max_new_tokens: usize,
    /// Tokens already generated when the replica died (lost work).
    pub generated: usize,
}

pub struct Coordinator<E: Engine> {
    cfg: ServingConfig,
    policy: KernelPolicy,
    pub kv: KvCacheManager,
    pub engine: E,
    queue: VecDeque<Sequence>,
    running: RunningSet,
    seqs: SeqArena,
    pub metrics: Metrics,
    /// Registered prefix groups, in registration order: (id, token len).
    prefixes: Vec<(PrefixId, usize)>,
    /// Target of group-less `submit` calls: the prefix installed by
    /// `set_shared_prefix` (or the first registered group).
    default_prefix: Option<PrefixId>,
    /// Prefix groups retired by the router (migrated away): kept
    /// registered while any of their sequences is queued or running,
    /// released as soon as the group drains.
    draining: Vec<PrefixId>,
    recently_finished: Vec<SeqId>,
    /// `metrics.decode_seconds` stamped at each of the last
    /// `SERVICE_RATE_WINDOW` completions (the windowed mu estimate).
    completion_marks: VecDeque<f64>,
    /// When true (default), finished sequences stay resident in the
    /// arena (and `take_finished` logs them) so callers can read them
    /// back after retirement; ids are never reused.  The cluster
    /// simulator switches this off so million-request runs hold
    /// O(max outstanding) sequences instead of O(total served).
    retain_finished: bool,
    /// Decode-batch scratch recycled across iterations (DESIGN.md §17):
    /// `step` hands the batch's three vectors back after the engine
    /// call, so a million-iteration run reuses the same allocations
    /// instead of building fresh ones every step.
    batch_scratch: DecodeBatch,
    /// Per-group member buckets for the multi-tenant partition path,
    /// recycled the same way (inner vectors keep their capacity).
    members_scratch: Vec<Vec<SeqId>>,
    /// Canonical run clock: accumulated engine-reported seconds.
    now: f64,
}

impl<E: Engine> Coordinator<E> {
    pub fn new(
        cfg: ServingConfig,
        policy: KernelPolicy,
        kv: KvCacheManager,
        engine: E,
    ) -> Result<Self> {
        cfg.validate()?;
        Ok(Coordinator {
            cfg,
            policy,
            kv,
            engine,
            queue: VecDeque::new(),
            running: RunningSet::new(),
            seqs: SeqArena::new(),
            metrics: Metrics::new(Clock::Simulated),
            prefixes: Vec::new(),
            default_prefix: None,
            draining: Vec::new(),
            recently_finished: Vec::new(),
            completion_marks: VecDeque::new(),
            retain_finished: true,
            batch_scratch: DecodeBatch::default(),
            members_scratch: Vec::new(),
            now: 0.0,
        })
    }

    /// Toggle finished-sequence retention (see the field doc).  Off:
    /// retired ids are recycled by later submissions, `sequence(id)`
    /// stops resolving finished requests, and `take_finished` stays
    /// empty — modeled times and metrics are bit-identical either way.
    pub fn set_retain_finished(&mut self, retain: bool) {
        self.retain_finished = retain;
    }

    /// High-water mark of sequence-arena slots (reserved + resident).
    pub fn arena_peak(&self) -> usize {
        self.seqs.peak()
    }

    /// Currently occupied sequence-arena slots.
    pub fn arena_occupied(&self) -> usize {
        self.seqs.occupied()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Fast-forward the clock to an external event time (a cluster
    /// router delivering a timed arrival to an idle replica).  Never
    /// moves backward; the idle span counts toward elapsed wall time
    /// but not decode time.
    pub fn advance_clock(&mut self, to: f64) {
        if to > self.now {
            self.metrics.advance_sim_time(to - self.now);
            self.now = to;
        }
    }

    /// Register a prefix group (one tenant's system prompt) and run its
    /// prefill.  For Typhoon/Naive the uncompressed copy is
    /// materialized too.  The first registered group becomes the
    /// default target of group-less `submit` calls.
    pub fn register_prefix_group(&mut self, tokens: &[u32]) -> Result<PrefixId> {
        let id = self.kv.register_shared_prefix(tokens)?;
        let secs = self.engine.prepare_shared(id, tokens, self.cfg.kernel)?;
        if self.cfg.kernel.reads_shared_naive() {
            self.kv.expand_shared_prefix(id)?;
        }
        self.now += secs;
        self.metrics.advance_sim_time(secs);
        self.metrics.shared_prefills += 1;
        self.prefixes.push((id, tokens.len()));
        if self.default_prefix.is_none() {
            self.default_prefix = Some(id);
        }
        Ok(id)
    }

    /// Adopt a prefix group whose pages arrive over the interconnect
    /// (cross-replica migration): the KV payload — latent pages plus
    /// the uncompressed copy when the source held one — is installed
    /// as-is, so **no prefill runs** and no engine time is charged; the
    /// cluster charges the modeled transfer separately via
    /// `charge_transfer`.  A Typhoon/Naive stack refuses an unexpanded
    /// export: materializing the uncompressed copy here would be
    /// unpriced work — expand at the source so the transfer carries
    /// (and prices) it.
    pub fn import_prefix_group(&mut self, export: &PrefixExport) -> Result<PrefixId> {
        let needs_expansion = self.cfg.kernel.reads_shared_naive();
        if needs_expansion && !export.expanded {
            return Err(anyhow!(
                "cannot adopt an unexpanded prefix into a {} stack: expand it at \
                 the source so the transfer prices the uncompressed copy",
                self.cfg.kernel.as_str()
            ));
        }
        let id = self.kv.import_prefix(export)?;
        self.metrics.prefix_imports += 1;
        self.prefixes.push((id, export.tokens.len()));
        if self.default_prefix.is_none() {
            self.default_prefix = Some(id);
        }
        Ok(id)
    }

    /// Retire a prefix group this replica no longer homes (its pages
    /// migrated away): the group stops being a valid `submit_to`
    /// target's long-term home but stays registered while any of its
    /// sequences is queued or running; its pages are released the
    /// moment it drains.  Returns true when the release happened
    /// immediately.
    pub fn retire_prefix_group(&mut self, prefix: PrefixId) -> Result<bool> {
        if self.prefix_len(prefix).is_none() {
            return Err(anyhow!("unknown prefix group {prefix}"));
        }
        if !self.draining.contains(&prefix) {
            self.draining.push(prefix);
        }
        self.release_drained()?;
        Ok(self.prefix_len(prefix).is_none())
    }

    /// Release every draining group whose last sequence has retired.
    fn release_drained(&mut self) -> Result<()> {
        let mut i = 0;
        while i < self.draining.len() {
            let pid = self.draining[i];
            let drained = self.kv.prefix(pid).map(|p| p.users == 0 && p.pending == 0);
            if drained == Some(false) {
                i += 1;
                continue;
            }
            if drained == Some(true) {
                self.kv.release_shared_prefix(pid)?;
            }
            // (`None`: already released out-of-band through the public
            // `kv` handle — drop the registry bookkeeping only.)
            self.prefixes.retain(|&(id, _)| id != pid);
            if self.default_prefix == Some(pid) {
                self.default_prefix = self.prefixes.first().map(|&(id, _)| id);
            }
            self.draining.swap_remove(i);
        }
        Ok(())
    }

    /// Charge modeled interconnect time (an inbound page migration) to
    /// this replica's clock.  Like idle fast-forwarding it counts
    /// toward elapsed wall time, never toward decode time.
    pub fn charge_transfer(&mut self, seconds: f64) {
        self.now += seconds;
        self.metrics.advance_sim_time(seconds);
        self.metrics.transfer_seconds += seconds;
    }

    /// Router probe: observed completions per busy decode second (0
    /// until the replica has history) — the service rate SLO admission
    /// converts a TTFT target into a queue-depth threshold with, and
    /// replica autoscaling sums into the fleet's capacity estimate.
    ///
    /// The estimate is **windowed** over the last
    /// `SERVICE_RATE_WINDOW` completions: a lifetime
    /// `requests_completed / decode_seconds` ratio mixes every regime
    /// the replica ever served (a replica that idled through a lull
    /// keeps reporting its old burst-time mu, so the SLO threshold
    /// never recovers).  With too little history — or when the whole
    /// window retired inside one iteration — it falls back to the
    /// lifetime ratio.
    pub fn service_rate(&self) -> f64 {
        let n = self.completion_marks.len();
        if n >= 2 {
            let span = self.completion_marks[n - 1] - self.completion_marks[0];
            if span > 0.0 {
                return (n - 1) as f64 / span;
            }
        }
        if self.metrics.decode_seconds > 0.0 {
            self.metrics.requests_completed as f64 / self.metrics.decode_seconds
        } else {
            0.0
        }
    }

    /// Install the shared prefix (system prompt) and run its prefill —
    /// the classic single-tenant entry point.  Registers a group and
    /// makes it the default `submit` target.
    pub fn set_shared_prefix(&mut self, tokens: &[u32]) -> Result<PrefixId> {
        let id = self.register_prefix_group(tokens)?;
        self.default_prefix = Some(id);
        Ok(id)
    }

    /// Shared length of the default prefix group (0 when none).
    pub fn shared_len(&self) -> usize {
        self.default_prefix.and_then(|p| self.prefix_len(p)).unwrap_or(0)
    }

    /// Token length of a registered prefix group.
    pub fn prefix_len(&self, prefix: PrefixId) -> Option<usize> {
        self.prefixes.iter().find(|&&(id, _)| id == prefix).map(|&(_, l)| l)
    }

    /// Registered prefix groups in registration order.
    pub fn prefix_groups(&self) -> &[(PrefixId, usize)] {
        &self.prefixes
    }

    /// Enqueue a request against the default prefix group.
    pub fn submit(&mut self, req: &Request) -> Result<SeqId> {
        let prefix = self
            .default_prefix
            .ok_or_else(|| anyhow!("no shared prefix installed"))?;
        self.submit_to(req, prefix)
    }

    /// Enqueue a request against a specific prefix group.  The group's
    /// pages are pinned while the request is queued, admitted or
    /// running — `KvCacheManager::release_shared_prefix` refuses until
    /// every sequence of the group has retired.
    pub fn submit_to(&mut self, req: &Request, prefix: PrefixId) -> Result<SeqId> {
        self.submit_to_at(req, prefix, self.now)
    }

    /// `submit_to` with an explicit submission timestamp — a cluster
    /// router delivering a timed arrival that occurred while this
    /// replica was mid-iteration anchors TTFT/latency at the *arrival*
    /// time, so queueing delay is not silently dropped.  Clamped to the
    /// current clock (a submission cannot postdate it).
    pub fn submit_to_at(
        &mut self,
        req: &Request,
        prefix: PrefixId,
        submitted_at: f64,
    ) -> Result<SeqId> {
        if self.prefix_len(prefix).is_none() {
            return Err(anyhow!("unknown prefix group {prefix}"));
        }
        self.kv.pin_pending(prefix)?;
        let id = self.seqs.reserve();
        let prompt = req.prompt_tokens.min(self.cfg.max_seq_len.saturating_sub(1));
        let budget = req.max_new_tokens.min(self.cfg.max_seq_len - prompt);
        let seq = Sequence::new(id, prefix, prompt, budget, submitted_at.min(self.now));
        self.queue.push_back(seq);
        Ok(id)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Router probe: total outstanding work on this replica (queued
    /// behind the batch + resident in it).
    pub fn load(&self) -> usize {
        self.queue.len() + self.running.len()
    }

    /// Router probe: fraction of decode-batch slots occupied.
    pub fn occupancy(&self) -> f64 {
        self.running.len() as f64 / self.effective_max_batch() as f64
    }

    /// Router probe: can the KV pool admit a request with this many
    /// non-shared context tokens right now?
    pub fn can_admit_now(&self, context_len: usize) -> bool {
        self.kv.can_admit(context_len)
    }

    pub fn sequence(&self, id: SeqId) -> Option<&Sequence> {
        self.seqs.get(id)
    }

    fn effective_max_batch(&self) -> usize {
        self.cfg.max_batch.min(self.engine.max_batch())
    }

    /// Admit queued requests into free batch slots (continuous batching).
    fn admit(&mut self) -> Result<()> {
        let max_batch = self.effective_max_batch();
        let free = max_batch.saturating_sub(self.running.len());
        if free == 0 || free < self.cfg.admit_hysteresis.min(max_batch) {
            return Ok(());
        }
        let mut wave: Vec<PrefillRequest> = Vec::new();
        while self.running.len() + wave.len() < max_batch {
            let Some(front) = self.queue.front() else { break };
            // Context includes regenerated tokens for preempted requeues.
            if !self.kv.can_admit(front.context_len()) {
                break; // KV backpressure: wait for capacity
            }
            let mut seq = self.queue.pop_front().unwrap();
            self.kv.add_sequence(seq.id, seq.prefix, seq.context_len())?;
            self.kv.unpin_pending(seq.prefix)?;
            seq.state = SeqState::Decoding;
            let shared_len = self.prefix_len(seq.prefix).unwrap_or(0);
            wave.push(PrefillRequest {
                seq: seq.id,
                context_len: seq.context_len(),
                shared_len,
            });
            self.seqs.install(seq);
        }
        if !wave.is_empty() {
            let secs = self.engine.prefill_requests(&wave)?;
            self.now += secs;
            self.metrics.advance_sim_time(secs);
            self.metrics.prefill_calls += 1;
            self.metrics.requests_admitted += wave.len() as u64;
            for r in &wave {
                self.running.push(r.seq);
            }
        }
        Ok(())
    }

    /// Preempt the most-recently-admitted running sequence: release its
    /// pages and requeue it for recompute (vLLM-style recompute
    /// preemption).  Returns the victim, or None if nothing to preempt.
    fn preempt_one(&mut self, protect: SeqId) -> Result<Option<SeqId>> {
        let victim = self.running.last_except(protect);
        let Some(victim) = victim else { return Ok(None) };
        self.kv.remove_sequence(victim)?;
        self.engine.release(victim);
        self.running.remove(victim);
        let mut seq = self.seqs.take(victim).expect("running seq exists");
        seq.state = SeqState::Queued;
        // Back in the queue: re-pin its group so the prefix cannot be
        // freed out from under a preempted (but unfinished) request.
        self.kv.pin_pending(seq.prefix)?;
        self.queue.push_front(seq);
        self.metrics.preemptions += 1;
        Ok(Some(victim))
    }

    /// Reserve a page slot for every running sequence's next token,
    /// preempting under memory pressure.  If even a lone sequence cannot
    /// grow, it is force-finished at its current length.
    fn reserve_next_token(&mut self) -> Result<Vec<SeqId>> {
        let mut force_finished = Vec::new();
        for id in self.running.snapshot() {
            if !self.running.contains(id) {
                continue; // already preempted this round
            }
            loop {
                match self.kv.append_token(id) {
                    Ok(()) => break,
                    Err(_) => {
                        if self.preempt_one(id)?.is_none() {
                            // Nothing left to evict: out of pool for this
                            // sequence — finish it where it stands.
                            force_finished.push(id);
                            break;
                        }
                    }
                }
            }
        }
        Ok(force_finished)
    }

    /// Book a finished request in the metrics: completion count,
    /// end-to-end latency, TTFT and TPOT (the latter only when
    /// defined).  Shared by the normal and force-finish paths.
    fn record_completion(&mut self, id: SeqId) {
        self.metrics.requests_completed += 1;
        let seq = self.seqs.get(id).expect("finished seq exists");
        if let Some(lat) = seq.latency() {
            self.metrics.request_latency.push(lat);
        }
        if let Some(t) = seq.ttft() {
            self.metrics.ttft.push(t);
        }
        if let Some(t) = seq.tpot() {
            self.metrics.tpot.push(t);
        }
        self.completion_marks.push_back(self.metrics.decode_seconds);
        if self.completion_marks.len() > SERVICE_RATE_WINDOW {
            self.completion_marks.pop_front();
        }
        if self.retain_finished {
            self.recently_finished.push(id);
        } else {
            // Million-request mode: recycle the slot immediately.
            self.seqs.free(id);
        }
    }

    /// Partition the running set into prefix groups, preserving
    /// admission order inside each group; groups appear in prefix
    /// registration order (deterministic; modeled times are
    /// order-independent anyway — exact u64 sums).  The fall-back rule
    /// is applied per group.
    fn build_decode_batch(&mut self) -> DecodeBatch {
        let mut batch = std::mem::take(&mut self.batch_scratch);
        debug_assert!(
            batch.seqs.is_empty() && batch.context_lens.is_empty() && batch.groups.is_empty(),
            "decode-batch scratch must come back cleared"
        );
        // Fast path: one registered group (the paper's single-prompt
        // protocol and the dominant sweep configuration) — the batch
        // *is* the group; no partition, and with the recycled scratch
        // the steady-state hot path allocates nothing at all.
        if let [(prefix, shared_len)] = self.prefixes[..] {
            batch.seqs.extend_from_slice(self.running.ids());
            batch.context_lens.extend(batch.seqs.iter().map(|&id| {
                self.seqs.get(id).expect("running seq exists").context_len()
            }));
            let kernel = self.policy.select_group(
                batch.seqs.len(),
                shared_len,
                mean_len(&batch.context_lens),
            );
            batch.groups.push(BatchGroup {
                prefix,
                shared_len,
                kernel,
                start: 0,
                len: batch.seqs.len(),
            });
            return batch;
        }
        // General path: bucket members by registration index (small
        // linear scan over the tenant registry, no hashing).  The
        // buckets are recycled scratch too — drained below, capacity
        // kept across iterations.
        self.members_scratch.resize_with(self.prefixes.len(), Vec::new);
        debug_assert!(
            self.members_scratch.iter().all(Vec::is_empty),
            "member scratch must come back cleared"
        );
        for id in self.running.iter() {
            let p = self.seqs.get(id).expect("running seq exists").prefix;
            let gi = self
                .prefixes
                .iter()
                .position(|&(pid, _)| pid == p)
                .expect("running sequence's prefix is registered");
            self.members_scratch[gi].push(id);
        }
        let n = self.running.len();
        batch.seqs.reserve(n);
        batch.context_lens.reserve(n);
        for gi in 0..self.members_scratch.len() {
            if self.members_scratch[gi].is_empty() {
                continue;
            }
            let (prefix, shared_len) = self.prefixes[gi];
            let start = batch.seqs.len();
            for i in 0..self.members_scratch[gi].len() {
                let id = self.members_scratch[gi][i];
                batch
                    .context_lens
                    .push(self.seqs.get(id).expect("running seq exists").context_len());
                batch.seqs.push(id);
            }
            self.members_scratch[gi].clear();
            let kernel = self.policy.select_group(
                batch.seqs.len() - start,
                shared_len,
                mean_len(&batch.context_lens[start..]),
            );
            batch.groups.push(BatchGroup {
                prefix,
                shared_len,
                kernel,
                start,
                len: batch.seqs.len() - start,
            });
        }
        batch
    }

    /// Hand a decode batch's vectors back to the scratch — cleared,
    /// capacity kept (see `batch_scratch`).
    fn recycle_batch(&mut self, mut batch: DecodeBatch) {
        batch.seqs.clear();
        batch.context_lens.clear();
        batch.groups.clear();
        self.batch_scratch = batch;
    }

    /// One scheduler step: admit, decode one iteration, retire finished.
    /// Returns false when there is nothing left to do.
    pub fn step(&mut self) -> Result<bool> {
        self.admit()?;
        if self.running.is_empty() {
            return Ok(!self.queue.is_empty());
        }
        // Page reservation for this step's tokens (may preempt).
        let force_finished = self.reserve_next_token()?;
        self.running.remove_many(&force_finished);
        for id in force_finished {
            self.kv.remove_sequence(id)?;
            self.engine.release(id);
            let seq = self.seqs.get_mut(id).unwrap();
            seq.state = SeqState::Finished;
            seq.finished_at = Some(self.now);
            // Out-of-pool completions are completions too: their
            // latency counts like any normally-finished request's.
            self.record_completion(id);
        }
        if !self.draining.is_empty() {
            self.release_drained()?;
        }
        if self.running.is_empty() {
            return Ok(!self.queue.is_empty());
        }

        let batch = self.build_decode_batch();
        let outcome = self.engine.decode(&batch)?;
        self.now += outcome.seconds;
        for g in &batch.groups {
            match g.kernel {
                // Family counters: the AMLA variants are the same two
                // execution strategies with rescaled arithmetic.
                KernelKind::Typhoon | KernelKind::TyphoonAmla => {
                    self.metrics.typhoon_iters += 1
                }
                KernelKind::Absorb | KernelKind::AmlaAbsorb => self.metrics.absorb_iters += 1,
                KernelKind::Naive => self.metrics.naive_iters += 1,
            }
        }
        if batch.uniform_kernel().is_none() {
            self.metrics.mixed_iters += 1;
        }
        self.metrics.breakdown.add(&outcome.breakdown);

        // Every running sequence produced one token (pages were
        // reserved above).
        let mut finished: Vec<SeqId> = Vec::new();
        for &id in &batch.seqs {
            let seq = self.seqs.get_mut(id).unwrap();
            let done = seq.advance(self.now) || seq.context_len() >= self.cfg.max_seq_len;
            if done {
                seq.state = SeqState::Finished;
                seq.finished_at.get_or_insert(self.now);
                finished.push(id);
            }
        }
        self.running.remove_many(&finished);
        for id in &finished {
            self.kv.remove_sequence(*id)?;
            self.engine.release(*id);
            self.record_completion(*id);
        }
        if !self.draining.is_empty() {
            self.release_drained()?;
        }
        self.metrics
            .record_iteration(outcome.seconds, batch.seqs.len(), batch.seqs.len() as u64);
        self.recycle_batch(batch);
        Ok(true)
    }

    /// Crash teardown (cluster failover, DESIGN.md §14): tear down
    /// every running and queued sequence — releasing suffix pages,
    /// engine slots, and pending pins — and hand back what a survivor
    /// needs to redo the work.  In-flight requests are *re-queued*, not
    /// dropped: each one's already-generated tokens are booked as
    /// `lost_tokens` (the restart regenerates them elsewhere).  The
    /// replica's prefix groups stay registered; after this they have no
    /// users or pending pins, so a subsequent `retire_prefix_group`
    /// releases their pages immediately.
    pub fn fail_and_extract(&mut self) -> Result<Vec<RequeuedWork>> {
        let mut out = Vec::with_capacity(self.running.len() + self.queue.len());
        for id in self.running.snapshot() {
            self.kv.remove_sequence(id)?;
            self.engine.release(id);
            self.running.remove(id);
            let seq = self.seqs.take(id).expect("running seq exists");
            self.seqs.free_reserved(id);
            self.metrics.lost_tokens += seq.generated as u64;
            self.metrics.requeued_requests += 1;
            out.push(RequeuedWork {
                prefix: seq.prefix,
                prompt_tokens: seq.prompt_tokens,
                max_new_tokens: seq.max_new_tokens,
                generated: seq.generated,
            });
        }
        // Queued sequences hold only their pending pin (a preempted
        // requeue may still carry regenerated tokens — lost too).
        for seq in std::mem::take(&mut self.queue) {
            self.kv.unpin_pending(seq.prefix)?;
            self.seqs.free_reserved(seq.id);
            self.metrics.lost_tokens += seq.generated as u64;
            self.metrics.requeued_requests += 1;
            out.push(RequeuedWork {
                prefix: seq.prefix,
                prompt_tokens: seq.prompt_tokens,
                max_new_tokens: seq.max_new_tokens,
                generated: seq.generated,
            });
        }
        // Groups an outbound migration had already marked draining just
        // lost their last users/pins, and nothing will step this
        // coordinator again — sweep them now so a failed replica ends
        // at zero live pages.
        if !self.draining.is_empty() {
            self.release_drained()?;
        }
        Ok(out)
    }

    /// Sequences that finished since the last call (drained).
    pub fn take_finished(&mut self) -> Vec<SeqId> {
        std::mem::take(&mut self.recently_finished)
    }

    /// Drive until queue and batch drain.  Returns total modeled seconds.
    pub fn run_to_completion(&mut self) -> Result<f64> {
        while self.step()? {}
        Ok(self.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::sim;
    use crate::metrics::BreakdownTimers;

    /// Deterministic mock: fixed prefill/decode times, records calls.
    struct MockEngine {
        decode_calls: usize,
        batch_sizes: Vec<usize>,
        kernels: Vec<KernelKind>,
        /// Off by default: cloning every iteration's group layout is
        /// O(total iterations) memory — at 1M requests the transcript
        /// would dominate the run.  Tests that assert on group shapes
        /// opt in explicitly.
        record_groups: bool,
        groups_seen: Vec<Vec<BatchGroup>>,
    }

    impl MockEngine {
        fn new() -> Self {
            MockEngine {
                decode_calls: 0,
                batch_sizes: Vec::new(),
                kernels: Vec::new(),
                record_groups: false,
                groups_seen: Vec::new(),
            }
        }
    }

    impl Engine for MockEngine {
        fn prepare_shared(
            &mut self,
            _p: PrefixId,
            _tokens: &[u32],
            _k: KernelKind,
        ) -> Result<f64> {
            Ok(0.5)
        }

        fn prefill_requests(&mut self, _seqs: &[PrefillRequest]) -> Result<f64> {
            Ok(0.1)
        }

        fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome> {
            self.decode_calls += 1;
            self.batch_sizes.push(batch.seqs.len());
            // Single-prefix tests assert on the batch-wide kernel; mixed
            // iterations land in `groups_seen` only.
            if let Some(k) = batch.uniform_kernel() {
                self.kernels.push(k);
            }
            if self.record_groups {
                self.groups_seen.push(batch.groups.clone());
            }
            Ok(IterationOutcome { seconds: 0.01, breakdown: BreakdownTimers::default() })
        }

        fn release(&mut self, _seq: SeqId) {}
    }

    fn coordinator(max_batch: usize, b_theta: usize) -> Coordinator<MockEngine> {
        let cfg = ServingConfig {
            max_batch,
            block_size: 16,
            max_seq_len: 256,
            total_blocks: 4096,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, b_theta);
        let kv = KvCacheManager::new(sim(), cfg.total_blocks, cfg.block_size);
        Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap()
    }

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request { id, prompt_tokens: prompt, max_new_tokens: gen }
    }

    #[test]
    fn runs_all_requests_to_completion() {
        let mut c = coordinator(4, 1);
        c.set_shared_prefix(&(0..64u32).collect::<Vec<_>>()).unwrap();
        for i in 0..10 {
            c.submit(&req(i, 8, 3)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 10);
        assert_eq!(c.metrics.tokens_generated, 30);
        assert_eq!(c.running(), 0);
        assert_eq!(c.queued(), 0);
        // All pages back except the shared prefix's.
        assert_eq!(c.kv.used_blocks(), 4); // 64 tokens / 16
    }

    /// The per-iteration group transcript is opt-in: with recording
    /// off (the default) the hot path never touches `groups_seen`, so
    /// a long run accumulates nothing there — not even one allocation.
    #[test]
    fn group_transcript_off_by_default_allocates_nothing() {
        let mut c = coordinator(4, 1);
        c.set_shared_prefix(&(0..64u32).collect::<Vec<_>>()).unwrap();
        for i in 0..10 {
            c.submit(&req(i, 8, 3)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.engine.decode_calls > 0);
        assert!(c.engine.groups_seen.is_empty());
        assert_eq!(
            c.engine.groups_seen.capacity(),
            0,
            "hot path must not allocate for the disabled transcript"
        );
    }

    /// The decode-batch scratch is recycled: after a run it holds the
    /// (cleared) vectors of the last iteration rather than fresh empty
    /// ones, so steady-state steps build their batch without
    /// allocating.
    #[test]
    fn decode_batch_scratch_is_recycled() {
        let mut c = coordinator(4, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..6 {
            c.submit(&req(i, 4, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.batch_scratch.seqs.is_empty(), "scratch comes back cleared");
        assert!(
            c.batch_scratch.seqs.capacity() >= 4,
            "the last iteration's vectors came back for reuse"
        );
        assert!(c.batch_scratch.groups.capacity() >= 1);
    }

    /// Non-retaining mode (the cluster's million-request setting):
    /// finished slots recycle, the arena stays bounded by outstanding
    /// work, and `take_finished` keeps no log.  Retaining mode keeps
    /// every finished sequence readable — the server loop's contract.
    #[test]
    fn retention_modes_bound_or_keep_finished_sequences() {
        let mut c = coordinator(2, 1);
        c.set_retain_finished(false);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..10 {
            c.submit(&req(i, 4, 1)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 10);
        assert_eq!(c.arena_occupied(), 0, "all slots recycled after drain");
        assert!(c.take_finished().is_empty(), "no finished log when not retaining");

        let mut c = coordinator(2, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..10 {
            c.submit(&req(i, 4, 1)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.arena_occupied(), 10, "retained finished sequences stay resident");
        assert_eq!(c.arena_peak(), 10);
        let ids = c.take_finished();
        assert_eq!(ids.len(), 10);
        assert!(ids.iter().all(|&id| c.sequence(id).is_some()));
    }

    /// Recycled ids keep per-request metrics intact: interleaved
    /// submissions against recycled slots complete exactly once each.
    #[test]
    fn recycled_ids_complete_exactly_once() {
        let mut c = coordinator(2, 1);
        c.set_retain_finished(false);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        let mut submitted = 0u64;
        for round in 0..5 {
            for i in 0..3u64 {
                c.submit(&req(round * 3 + i, 4, 2)).unwrap();
                submitted += 1;
            }
            c.run_to_completion().unwrap();
        }
        assert_eq!(c.metrics.requests_completed, submitted);
        assert_eq!(c.metrics.request_latency.len() as u64, submitted);
        assert!(
            c.arena_peak() <= 3,
            "slot reuse keeps the arena at the per-round width, got {}",
            c.arena_peak()
        );
    }

    #[test]
    fn batch_never_exceeds_max() {
        let mut c = coordinator(3, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..7 {
            c.submit(&req(i, 4, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.engine.batch_sizes.iter().all(|&b| b <= 3));
        assert!(c.engine.batch_sizes.contains(&3), "batch fills up");
    }

    #[test]
    fn continuous_batching_replaces_completed() {
        let mut c = coordinator(2, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        // One long, two short: the short ones cycle through slot 2.
        c.submit(&req(0, 4, 6)).unwrap();
        c.submit(&req(1, 4, 1)).unwrap();
        c.submit(&req(2, 4, 1)).unwrap();
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 3);
        assert_eq!(c.engine.batch_sizes[0], 2);
        assert_eq!(c.engine.batch_sizes[1], 2);
    }

    #[test]
    fn policy_fallback_at_small_batch() {
        let mut c = coordinator(8, 4);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..2 {
            c.submit(&req(i, 4, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.engine.kernels.iter().all(|&k| k == KernelKind::Absorb));
        assert_eq!(c.metrics.absorb_iters, c.metrics.decode_iterations);

        let mut c = coordinator(8, 4);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..8 {
            c.submit(&req(i, 4, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert!(c.engine.kernels.contains(&KernelKind::Typhoon));
    }

    #[test]
    fn kv_backpressure_blocks_admission() {
        // Tiny pool: shared prefix (1 page) + 3 pages => only 3 single-page
        // sequences fit at once.
        let cfg = ServingConfig {
            max_batch: 4,
            block_size: 16,
            max_seq_len: 64,
            total_blocks: 4,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, 1);
        let kv = KvCacheManager::new(sim(), 4, 16);
        let mut c = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        for i in 0..6 {
            c.submit(&req(i, 8, 2)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 6, "all served eventually");
        assert!(
            c.engine.batch_sizes.iter().all(|&b| b <= 3),
            "{:?}",
            c.engine.batch_sizes
        );
    }

    #[test]
    fn submit_without_prefix_errors() {
        let mut c = coordinator(2, 1);
        assert!(c.submit(&req(0, 4, 2)).is_err());
    }

    #[test]
    fn submit_to_unknown_group_errors() {
        let mut c = coordinator(2, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        assert!(c.submit_to(&req(0, 4, 2), 999).is_err());
    }

    #[test]
    fn token_conservation() {
        let mut c = coordinator(4, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        let budgets = [3usize, 1, 7, 2, 5];
        for (i, &g) in budgets.iter().enumerate() {
            c.submit(&req(i as u64, 4, g)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.tokens_generated as usize, budgets.iter().sum::<usize>());
        let by_batch: usize = c.engine.batch_sizes.iter().sum();
        assert_eq!(by_batch, budgets.iter().sum::<usize>());
    }

    #[test]
    fn preemption_under_kv_pressure() {
        // Pool: 1 prefix page + 3 pages.  Two sequences each eventually
        // need 2+ pages; one must be preempted and recomputed, and both
        // must still finish with their full budgets.
        let cfg = ServingConfig {
            max_batch: 3,
            block_size: 16,
            max_seq_len: 48,
            total_blocks: 4,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
        let kv = KvCacheManager::new(sim(), 4, 16);
        let mut c = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        c.submit(&req(0, 14, 20)).unwrap(); // grows past one page
        c.submit(&req(1, 14, 20)).unwrap();
        c.submit(&req(2, 14, 20)).unwrap();
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 3);
        assert!(c.metrics.preemptions > 0, "pressure must trigger preemption");
        assert_eq!(c.metrics.tokens_generated, 60, "budgets still met exactly");
        assert_eq!(c.kv.used_blocks(), 1, "only the prefix page remains");
    }

    #[test]
    fn max_seq_len_force_finishes() {
        let cfg = ServingConfig {
            max_batch: 1,
            block_size: 16,
            max_seq_len: 32,
            total_blocks: 64,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
        let kv = KvCacheManager::new(sim(), 64, 16);
        let mut c = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        c.submit(&req(0, 16, 100_000)).unwrap(); // budget clamped
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 1);
        let gen = c.metrics.tokens_generated as usize;
        assert!(gen <= 16, "generation stopped at context limit, got {gen}");
    }

    /// Out-of-pool force-finishes must record request latency exactly
    /// like normal completions.
    #[test]
    fn force_finished_latency_recorded() {
        // Pool: 1 prefix page + 1 page; a lone sequence exhausts it and
        // is force-finished with no preemption candidates.
        let cfg = ServingConfig {
            max_batch: 1,
            block_size: 16,
            max_seq_len: 64,
            total_blocks: 2,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
        let kv = KvCacheManager::new(sim(), 2, 16);
        let mut c = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        c.submit(&req(0, 8, 40)).unwrap(); // wants 3 pages, pool has 1
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 1);
        assert!(
            c.metrics.tokens_generated < 40,
            "must have been cut short, got {}",
            c.metrics.tokens_generated
        );
        assert_eq!(
            c.metrics.request_latency.len(),
            1,
            "force-finished request latency must be recorded"
        );
    }

    #[test]
    fn ttft_tpot_recorded_per_completion() {
        let mut c = coordinator(4, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        c.submit(&req(0, 4, 3)).unwrap();
        c.submit(&req(1, 4, 1)).unwrap();
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.ttft.len(), 2, "one TTFT per completed request");
        assert_eq!(c.metrics.tpot.len(), 1, "TPOT only for multi-token requests");
        assert!(c.metrics.ttft.values().iter().all(|&t| t > 0.0));
        assert!(c.metrics.tpot.values().iter().all(|&t| t > 0.0));
    }

    #[test]
    fn router_probes_and_clock_advance() {
        let mut c = coordinator(2, 1);
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        let t0 = c.now();
        c.advance_clock(t0 + 5.0);
        assert_eq!(c.now(), t0 + 5.0);
        c.advance_clock(t0); // never backward
        assert_eq!(c.now(), t0 + 5.0);
        assert_eq!(c.load(), 0);
        c.submit(&req(0, 4, 2)).unwrap();
        assert_eq!(c.load(), 1, "queued counts toward load");
        assert_eq!(c.occupancy(), 0.0);
        assert!(c.can_admit_now(4));
        c.step().unwrap();
        assert_eq!(c.load(), 1, "running counts toward load");
        assert_eq!(c.occupancy(), 0.5);
    }

    #[test]
    fn grouped_batch_partitions_by_prefix() {
        let mut c = coordinator(8, 1);
        c.engine.record_groups = true;
        let pa = c.register_prefix_group(&(0..64u32).collect::<Vec<_>>()).unwrap();
        let pb = c
            .register_prefix_group(&(1000..1032u32).collect::<Vec<_>>())
            .unwrap();
        assert_ne!(pa, pb);
        c.submit_to(&req(0, 4, 2), pa).unwrap();
        c.submit_to(&req(1, 4, 2), pb).unwrap();
        c.submit_to(&req(2, 4, 2), pa).unwrap();
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 3);
        let first = &c.engine.groups_seen[0];
        assert_eq!(first.len(), 2, "two prefix groups in the batch");
        let ga = first.iter().find(|g| g.prefix == pa).unwrap();
        let gb = first.iter().find(|g| g.prefix == pb).unwrap();
        assert_eq!((ga.len, ga.shared_len), (2, 64));
        assert_eq!((gb.len, gb.shared_len), (1, 32));
        // Slices tile the batch exactly.
        assert_eq!(ga.len + gb.len, c.engine.batch_sizes[0]);
    }

    /// The per-group fall-back rule: in one iteration a hot group runs
    /// Typhoon while a cold group (below B_theta) falls back to absorb.
    #[test]
    fn per_group_fallback_mixes_kernels() {
        let mut c = coordinator(8, 3); // B_theta = 3
        c.engine.record_groups = true;
        let hot = c.register_prefix_group(&(0..64u32).collect::<Vec<_>>()).unwrap();
        let cold = c
            .register_prefix_group(&(1000..1064u32).collect::<Vec<_>>())
            .unwrap();
        for i in 0..4 {
            c.submit_to(&req(i, 4, 2), hot).unwrap();
        }
        c.submit_to(&req(9, 4, 2), cold).unwrap();
        c.run_to_completion().unwrap();
        let first = &c.engine.groups_seen[0];
        let hot_g = first.iter().find(|g| g.prefix == hot).unwrap();
        let cold_g = first.iter().find(|g| g.prefix == cold).unwrap();
        assert_eq!(hot_g.kernel, KernelKind::Typhoon, "4 >= B_theta");
        assert_eq!(cold_g.kernel, KernelKind::Absorb, "1 < B_theta falls back");
        assert!(c.metrics.mixed_iters > 0, "mixed iteration recorded");
        assert!(c.metrics.typhoon_iters > 0 && c.metrics.absorb_iters > 0);
    }

    /// Single-prefix batches reduce to the legacy shape: one group
    /// covering the whole batch with the default prefix.
    #[test]
    fn single_prefix_reduces_to_legacy_batch() {
        let mut c = coordinator(4, 1);
        c.engine.record_groups = true;
        let p = c.set_shared_prefix(&(0..64u32).collect::<Vec<_>>()).unwrap();
        for i in 0..4 {
            c.submit(&req(i, 4, 3)).unwrap();
        }
        c.run_to_completion().unwrap();
        for (groups, &b) in c.engine.groups_seen.iter().zip(&c.engine.batch_sizes) {
            assert_eq!(groups.len(), 1);
            assert_eq!(groups[0].prefix, p);
            assert_eq!(groups[0].shared_len, 64);
            assert_eq!((groups[0].start, groups[0].len), (0, b));
        }
        assert_eq!(c.metrics.mixed_iters, 0);
    }

    /// Importing a migrated prefix adopts pages and expansion without a
    /// prefill: no engine time, no `shared_prefills` count.
    #[test]
    fn import_adopts_without_prefill() {
        let mut src = coordinator(4, 1);
        let pid = src.register_prefix_group(&(0..32u32).collect::<Vec<_>>()).unwrap();
        assert_eq!(src.metrics.shared_prefills, 1);
        let export = src.kv.export_prefix(pid).unwrap();

        let mut dst = coordinator(4, 1);
        let t0 = dst.now();
        let did = dst.import_prefix_group(&export).unwrap();
        assert_eq!(dst.now(), t0, "no prefill time charged");
        assert_eq!(dst.metrics.shared_prefills, 0);
        assert_eq!(dst.metrics.prefix_imports, 1);
        assert_eq!(dst.prefix_len(did), Some(32));
        assert!(dst.kv.prefix(did).unwrap().expanded, "typhoon config expands");
        // The imported group serves requests like a registered one.
        dst.submit_to(&req(0, 4, 2), did).unwrap();
        dst.run_to_completion().unwrap();
        assert_eq!(dst.metrics.requests_completed, 1);
    }

    /// A Typhoon stack refuses to adopt an unexpanded export — the
    /// expansion must be materialized (and priced) at the source.
    #[test]
    fn import_rejects_unexpanded_export_into_typhoon() {
        let cfg = ServingConfig {
            max_batch: 4,
            block_size: 16,
            max_seq_len: 256,
            total_blocks: 64,
            kernel: KernelKind::Absorb,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
        let kv = KvCacheManager::new(sim(), 64, 16);
        let mut absorb_src = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        let pid = absorb_src
            .register_prefix_group(&(0..32u32).collect::<Vec<_>>())
            .unwrap();
        let export = absorb_src.kv.export_prefix(pid).unwrap();
        assert!(!export.expanded, "absorb stacks keep latent-only prefixes");

        let mut typhoon_dst = coordinator(4, 1);
        assert!(typhoon_dst.import_prefix_group(&export).is_err());
        // An absorb destination adopts it fine.
        let cfg = ServingConfig {
            max_batch: 4,
            block_size: 16,
            max_seq_len: 256,
            total_blocks: 64,
            kernel: KernelKind::Absorb,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
        let kv = KvCacheManager::new(sim(), 64, 16);
        let mut absorb_dst = Coordinator::new(cfg, policy, kv, MockEngine::new()).unwrap();
        let did = absorb_dst.import_prefix_group(&export).unwrap();
        assert!(!absorb_dst.kv.prefix(did).unwrap().expanded);
    }

    /// Retiring a migrated-away group defers the page release until its
    /// last sequence drains, then frees everything.
    #[test]
    fn retire_releases_after_drain() {
        let mut c = coordinator(2, 1);
        let pid = c.register_prefix_group(&(0..32u32).collect::<Vec<_>>()).unwrap();
        c.submit_to(&req(0, 4, 3), pid).unwrap();
        c.step().unwrap(); // admit + decode one token
        assert!(!c.retire_prefix_group(pid).unwrap(), "live group defers release");
        assert!(c.prefix_len(pid).is_some(), "still registered while draining");
        c.run_to_completion().unwrap();
        assert!(c.prefix_len(pid).is_none(), "released at drain");
        assert_eq!(c.kv.used_blocks(), 0, "prefix pages returned");
        assert!(c.retire_prefix_group(pid).is_err(), "unknown after release");
    }

    #[test]
    fn retire_unused_group_releases_immediately() {
        let mut c = coordinator(2, 1);
        let pid = c.register_prefix_group(&(0..16u32).collect::<Vec<_>>()).unwrap();
        assert!(c.retire_prefix_group(pid).unwrap());
        assert_eq!(c.kv.used_blocks(), 0);
        assert!(c.prefix_len(pid).is_none());
    }

    /// Inbound migration transfer time is wall time, never decode time.
    #[test]
    fn charge_transfer_advances_wall_not_decode() {
        let mut c = coordinator(2, 1);
        let t0 = c.now();
        c.charge_transfer(0.25);
        assert_eq!(c.now(), t0 + 0.25);
        assert_eq!(c.metrics.transfer_seconds, 0.25);
        assert_eq!(c.metrics.decode_seconds, 0.0);
        assert_eq!(c.service_rate(), 0.0, "no completions yet");
    }

    /// Engine whose decode pace changes mid-run: `slow_iters` slow
    /// iterations, then fast ones — the two-regime history the
    /// windowed service-rate estimate must track.
    struct PacedEngine {
        iters: usize,
        slow_iters: usize,
        slow: f64,
        fast: f64,
    }

    impl Engine for PacedEngine {
        fn prepare_shared(
            &mut self,
            _p: PrefixId,
            _tokens: &[u32],
            _k: KernelKind,
        ) -> Result<f64> {
            Ok(0.0)
        }

        fn prefill_requests(&mut self, _seqs: &[PrefillRequest]) -> Result<f64> {
            Ok(0.0)
        }

        fn decode(&mut self, _batch: &DecodeBatch) -> Result<IterationOutcome> {
            let seconds = if self.iters < self.slow_iters { self.slow } else { self.fast };
            self.iters += 1;
            Ok(IterationOutcome { seconds, breakdown: BreakdownTimers::default() })
        }

        fn release(&mut self, _seq: SeqId) {}
    }

    /// The windowed service rate recovers after a slow burst: once the
    /// replica is back to fast completions, `service_rate` reports the
    /// *recent* mu, not the lifetime mix, so the SLO threshold derived
    /// from it recovers too.
    #[test]
    fn service_rate_window_recovers_after_a_burst() {
        let cfg = ServingConfig {
            max_batch: 1,
            block_size: 16,
            max_seq_len: 256,
            total_blocks: 4096,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, 1);
        let kv = KvCacheManager::new(sim(), cfg.total_blocks, cfg.block_size);
        let engine = PacedEngine { iters: 0, slow_iters: 100, slow: 1.0, fast: 1e-3 };
        let mut c = Coordinator::new(cfg, policy, kv, engine).unwrap();
        c.set_shared_prefix(&(0..16u32).collect::<Vec<_>>()).unwrap();
        // One request per iteration (max_batch 1, one generated token).
        for i in 0..200u64 {
            c.submit(&req(i, 4, 1)).unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 200);
        let lifetime = c.metrics.requests_completed as f64 / c.metrics.decode_seconds;
        assert!(lifetime < 3.0, "lifetime mu is dominated by the slow burst: {lifetime}");
        let windowed = c.service_rate();
        assert!(
            windowed > 100.0 * lifetime,
            "windowed mu must track the fast regime: {windowed} vs lifetime {lifetime}"
        );
        // The SLO threshold recovers with it: a 0.1 s TTFT target
        // tolerates a real backlog again instead of spilling everything.
        let slo = crate::policy::SloAdmission::new(Some(0.1));
        let recovered = slo.spill_depth(windowed, 0.0, 1);
        let stale = slo.spill_depth(lifetime, 0.0, 1);
        assert!(
            recovered > stale,
            "threshold must recover after the burst: {recovered} vs {stale}"
        );
    }

    /// A registered group's pages cannot be freed while any of its
    /// sequences is queued or running.
    #[test]
    fn queued_sequences_pin_their_prefix() {
        let mut c = coordinator(1, 1);
        let pa = c.register_prefix_group(&(0..16u32).collect::<Vec<_>>()).unwrap();
        let pb = c
            .register_prefix_group(&(100..116u32).collect::<Vec<_>>())
            .unwrap();
        // pb's only request sits queued behind pa's (max_batch = 1).
        c.submit_to(&req(0, 4, 50), pa).unwrap();
        c.submit_to(&req(1, 4, 2), pb).unwrap();
        c.step().unwrap(); // admits pa's request only
        assert_eq!(c.queued(), 1);
        assert!(
            c.kv.release_shared_prefix(pb).is_err(),
            "queued sequence must pin its group"
        );
        assert!(
            c.kv.release_shared_prefix(pa).is_err(),
            "running sequence must pin its group"
        );
        c.run_to_completion().unwrap();
        c.kv.release_shared_prefix(pb).unwrap();
        c.kv.release_shared_prefix(pa).unwrap();
    }

    /// Crash teardown re-queues every in-flight sequence (running and
    /// queued), books the lost work, and leaves the prefix groups
    /// releasable — the invariant the cluster failover path builds on.
    #[test]
    fn fail_and_extract_requeues_everything_and_unpins() {
        let mut c = coordinator(2, 1);
        let pid = c.register_prefix_group(&(0..16u32).collect::<Vec<_>>()).unwrap();
        c.submit_to(&req(0, 4, 10), pid).unwrap();
        c.submit_to(&req(1, 4, 10), pid).unwrap();
        c.submit_to(&req(2, 4, 10), pid).unwrap(); // stays queued (max_batch 2)
        c.step().unwrap(); // admit two, decode one token each
        assert_eq!(c.running(), 2);
        assert_eq!(c.queued(), 1);
        let work = c.fail_and_extract().unwrap();
        assert_eq!(work.len(), 3, "running and queued both extracted");
        assert_eq!(c.running(), 0);
        assert_eq!(c.queued(), 0);
        assert_eq!(c.metrics.requeued_requests, 3);
        assert_eq!(
            c.metrics.lost_tokens,
            work.iter().map(|w| w.generated as u64).sum::<u64>()
        );
        assert!(c.metrics.lost_tokens >= 2, "the running pair had generated");
        assert!(work.iter().all(|w| w.prefix == pid && w.prompt_tokens == 4));
        assert!(work.iter().all(|w| w.max_new_tokens == 10));
        // No users, no pending pins: the group releases immediately.
        assert!(c.retire_prefix_group(pid).unwrap());
        assert_eq!(c.kv.used_blocks(), 0, "a failed replica holds zero live pages");
    }
}
