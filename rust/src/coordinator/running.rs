//! Order-preserving membership structure for the running decode batch.
//!
//! The scheduler previously kept a plain `Vec<SeqId>` and answered
//! membership with `Vec::contains` inside per-sequence loops
//! (`reserve_next_token`, retirement), an O(B²) pattern per decode step
//! at B up to 1024.  `RunningSet` pairs the admission-ordered vector
//! (batch order is observable: it fixes `DecodeBatch::seqs` and the
//! per-sequence `context_lens` layout, so it must be preserved exactly)
//! with a position index for O(1) membership and O(1) position lookup;
//! removal compacts the tail (O(tail), amortized far below the old
//! full-vector scans and allocation-heavy `clone`+`retain` pairs).
//!
//! Since PR 7 `SeqId`s are dense arena indices (`coordinator::arena`),
//! so the position index is a plain sparse vector — no hashing on the
//! per-token membership checks, and its footprint is bounded by the
//! highest outstanding id, not total ids ever issued.

use crate::kvcache::SeqId;

/// Sentinel for "not running" in the sparse position index.
const ABSENT: usize = usize::MAX;

#[derive(Debug, Default)]
pub struct RunningSet {
    /// Admission order (the decode-batch order).
    order: Vec<SeqId>,
    /// SeqId -> index into `order` (`ABSENT` when not running),
    /// indexed directly by the dense id.
    pos: Vec<usize>,
}

impl RunningSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.pos.get(id as usize).is_some_and(|&p| p != ABSENT)
    }

    /// The batch in admission order.
    pub fn ids(&self) -> &[SeqId] {
        &self.order
    }

    pub fn iter(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.order.iter().copied()
    }

    /// Append at the end of the admission order.  Panics on duplicates
    /// (a sequence is running at most once — scheduler invariant).
    pub fn push(&mut self, id: SeqId) {
        let n = self.order.len();
        let i = id as usize;
        if i >= self.pos.len() {
            self.pos.resize(i + 1, ABSENT);
        }
        assert!(self.pos[i] == ABSENT, "sequence {id} already running");
        self.pos[i] = n;
        self.order.push(id);
    }

    /// The most recently admitted sequence other than `protect`
    /// (the preemption victim rule).
    pub fn last_except(&self, protect: SeqId) -> Option<SeqId> {
        self.order.iter().rev().copied().find(|&s| s != protect)
    }

    /// Remove `id`, preserving the order of the remaining sequences.
    /// Returns false if it was not present.
    pub fn remove(&mut self, id: SeqId) -> bool {
        let Some(p) = self.pos.get_mut(id as usize) else { return false };
        let idx = *p;
        if idx == ABSENT {
            return false;
        }
        *p = ABSENT;
        self.order.remove(idx);
        for (i, &s) in self.order.iter().enumerate().skip(idx) {
            self.pos[s as usize] = i;
        }
        true
    }

    /// Remove a batch of ids with one compaction + one reindex pass —
    /// O(B) total rather than O(k*B) repeated `remove` calls (the
    /// retire path can drop a whole admission wave in one step).
    /// Ids not present are ignored.
    pub fn remove_many(&mut self, ids: &[SeqId]) {
        if ids.is_empty() {
            return;
        }
        for &id in ids {
            if let Some(p) = self.pos.get_mut(id as usize) {
                *p = ABSENT;
            }
        }
        self.order.retain(|&s| self.pos[s as usize] != ABSENT);
        for (i, &s) in self.order.iter().enumerate() {
            self.pos[s as usize] = i;
        }
    }

    /// Snapshot of the current batch (for iteration while mutating).
    pub fn snapshot(&self) -> Vec<SeqId> {
        self.order.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_contains_remove_preserves_order() {
        let mut r = RunningSet::new();
        for id in [5u64, 3, 9, 7] {
            r.push(id);
        }
        assert_eq!(r.len(), 4);
        assert!(r.contains(9));
        assert!(!r.contains(4));
        assert!(r.remove(3));
        assert!(!r.remove(3), "double remove is a no-op");
        assert_eq!(r.ids(), &[5, 9, 7]);
        assert!(r.contains(7));
        assert!(!r.contains(3));
        // Positions stay consistent after the shift.
        assert!(r.remove(9));
        assert_eq!(r.ids(), &[5, 7]);
        assert!(r.contains(5) && r.contains(7));
    }

    #[test]
    fn last_except_skips_protected() {
        let mut r = RunningSet::new();
        r.push(1);
        r.push(2);
        r.push(3);
        assert_eq!(r.last_except(3), Some(2));
        assert_eq!(r.last_except(0), Some(3));
        r.remove(2);
        r.remove(3);
        assert_eq!(r.last_except(1), None);
    }

    #[test]
    #[should_panic(expected = "already running")]
    fn duplicate_push_panics() {
        let mut r = RunningSet::new();
        r.push(1);
        r.push(1);
    }

    #[test]
    fn remove_many_matches_individual_removes() {
        let mut a = RunningSet::new();
        let mut b = RunningSet::new();
        for id in 0..10u64 {
            a.push(id);
            b.push(id);
        }
        let victims = [3u64, 7, 0, 9, 42]; // 42 absent: ignored
        a.remove_many(&victims);
        for &v in &victims {
            b.remove(v);
        }
        assert_eq!(a.ids(), b.ids());
        for id in 0..10u64 {
            assert_eq!(a.contains(id), b.contains(id), "{id}");
        }
        a.remove_many(&[]);
        assert_eq!(a.ids(), b.ids());
    }

    /// Randomized consistency vs a reference Vec.
    #[test]
    fn fuzz_matches_vec_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(12);
        let mut r = RunningSet::new();
        let mut reference: Vec<SeqId> = Vec::new();
        for step in 0..2000 {
            if reference.is_empty() || rng.next_f64() < 0.6 {
                let id = step as u64;
                r.push(id);
                reference.push(id);
            } else if rng.next_f64() < 0.3 {
                let k = rng.gen_range_usize(1, reference.len().min(4) + 1);
                let ids: Vec<SeqId> =
                    (0..k).map(|_| *rng.choose(&reference)).collect();
                r.remove_many(&ids);
                reference.retain(|s| !ids.contains(s));
            } else {
                let id = *rng.choose(&reference);
                assert!(r.remove(id));
                reference.retain(|&s| s != id);
            }
            assert_eq!(r.ids(), &reference[..], "step {step}");
            for &id in &reference {
                assert!(r.contains(id));
            }
        }
    }
}
