"""L1 performance tuning: structural analysis of the Pallas kernels.

interpret=True wallclock on CPU is *not* a TPU proxy (the interpreter
runs the grid as a Python loop over numpy ops), so per DESIGN.md §8 the
L1 perf pass optimizes *structure*: VMEM working-set per grid step and
MXU (128x128 systolic array) operand alignment, estimated from the
BlockSpecs.  Run as::

    cd python && python -m compile.tuning [--sweep]

``--sweep`` additionally times the interpret-mode kernels across
kv-tile sizes — useful only to confirm the interpreter is
grid-overhead-bound (changes <5%), not as a TPU signal.
"""

import argparse
import time
from dataclasses import dataclass

from .configs import CONFIGS, DEEPSEEK_V3, KIMI_K2, SIM

BYTES_F32 = 4
VMEM_BUDGET = 16 * 1024 * 1024  # ~16 MiB/core on current TPUs
MXU_SUBLANE = 8
MXU_LANE = 128


@dataclass
class KernelFootprint:
    """Per-grid-step VMEM residency and MXU alignment of one kernel."""

    name: str
    vmem_bytes: int
    #: (M, K, N) of every dot in the kernel body.
    contractions: list
    notes: str = ""

    @property
    def vmem_ok(self) -> bool:
        return self.vmem_bytes <= VMEM_BUDGET

    def mxu_aligned(self) -> list:
        """Whether each contraction's K and N dims map cleanly onto the
        (8, 128) MXU tile; M (batch rows) pads cheaply."""
        return [
            (k % MXU_SUBLANE == 0) and (n % MXU_LANE == 0 or n >= MXU_LANE)
            for (_, k, n) in self.contractions
        ]

    def report(self) -> str:
        aligned = self.mxu_aligned()
        frac = sum(aligned) / max(len(aligned), 1)
        return (
            f"{self.name:<42} vmem/step {self.vmem_bytes/2**20:7.2f} MiB "
            f"({'ok' if self.vmem_ok else 'OVER'})  "
            f"mxu-aligned {sum(aligned)}/{len(aligned)} ({frac:.0%}) {self.notes}"
        )


def naive_shared_footprint(cfg, b_tile, kv_tile) -> KernelFootprint:
    """One grid step of naive_shared: q [Bt,Dqk], k/v tiles, scratch."""
    d_qk, d_v = cfg.d_qk, cfg.d_v
    vmem = BYTES_F32 * (
        b_tile * d_qk                 # q block
        + kv_tile * d_qk              # k tile
        + kv_tile * d_v               # v tile
        + b_tile * kv_tile            # scores
        + b_tile * (2 + d_v)          # m, l, acc scratch
        + b_tile * d_v                # out block
    )
    return KernelFootprint(
        name=f"naive_shared[{cfg.name}] bt={b_tile} kt={kv_tile}",
        vmem_bytes=vmem,
        contractions=[
            (b_tile, d_qk, kv_tile),  # scores = q @ k.T
            (b_tile, kv_tile, d_v),   # acc += p @ v
        ],
    )


def absorb_batched_footprint(cfg, kv_tile) -> KernelFootprint:
    """One grid step of absorb_batched: all H heads, one latent tile."""
    h, d_l, d_r = cfg.n_heads, cfg.kv_lora_rank, cfg.d_rope
    vmem = BYTES_F32 * (
        h * d_l + h * d_r             # q_lat, q_rope
        + kv_tile * (d_l + d_r)       # ckv + krope tiles
        + h * kv_tile                 # scores
        + h * (2 + d_l)               # scratch
        + h * d_l                     # out
    )
    return KernelFootprint(
        name=f"absorb_batched[{cfg.name}] kt={kv_tile}",
        vmem_bytes=vmem,
        contractions=[
            (h, d_l, kv_tile),
            (h, d_r, kv_tile),
            (h, kv_tile, d_l),
        ],
    )


def typhoon_footprints(cfg, b_tile, kv_tile):
    return [
        naive_shared_footprint(cfg, b_tile, kv_tile),
        absorb_batched_footprint(cfg, kv_tile),
    ]


def structural_report(b_tile=64):
    lines = ["== L1 structural analysis (VMEM/step + MXU alignment) =="]
    for cfg in (SIM, DEEPSEEK_V3, KIMI_K2):
        for kv_tile in (64, 128, 256, 512):
            for fp in typhoon_footprints(cfg, min(b_tile, 128), kv_tile):
                lines.append(fp.report())
        lines.append("")
    return "\n".join(lines)


def interpret_sweep(b=16, ls=512, ln=128):
    """Time interpret-mode kernels across kv tiles.  CPU-only signal:
    expected to be flat (grid-loop bound), confirming there is nothing
    to chase at L1 on this substrate."""
    import jax.numpy as jnp
    import numpy as np

    from .kernels import absorb, naive

    cfg = SIM
    rng = np.random.default_rng(0)
    f32 = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q = f32(b, cfg.n_heads, cfg.d_qk)
    k = f32(ls, cfg.n_heads, cfg.d_qk)
    v = f32(ls, cfg.n_heads, cfg.d_v)
    q_lat = f32(b, cfg.n_heads, cfg.kv_lora_rank)
    q_rope = f32(b, cfg.n_heads, cfg.d_rope)
    ckv = f32(b, ln, cfg.kv_lora_rank)
    krope = f32(b, ln, cfg.d_rope)
    lens = jnp.full((b,), ln, jnp.int32)

    lines = [f"== interpret-mode kv-tile sweep (B={b}, Ls={ls}, Ln={ln}) =="]
    for tile in (64, 128, 256):
        if ls % tile or ln % tile:
            continue
        for name, fn in [
            ("naive_shared", lambda t=tile: naive.naive_shared_attention(
                q, k, v, ls, kv_tile=t)),
            ("absorb_batched", lambda t=tile: absorb.absorb_batched_attention(
                q_lat, q_rope, ckv, krope, lens, kv_tile=t, d_qk=cfg.d_qk)),
        ]:
            fn()  # warm
            t0 = time.perf_counter()
            reps = 3
            for _ in range(reps):
                o, _ = fn()
                o.block_until_ready()
            dt = (time.perf_counter() - t0) / reps
            lines.append(f"  {name:<16} kv_tile={tile:<4} {dt*1e3:8.1f} ms")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--b-tile", type=int, default=64)
    args = ap.parse_args()
    print(structural_report(args.b_tile))
    if args.sweep:
        print(interpret_sweep())


if __name__ == "__main__":
    main()
