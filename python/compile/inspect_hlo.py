"""L2 performance inspection: static analysis of the AOT HLO artifacts.

Parses the HLO text in ``artifacts/`` and reports the op-level facts
the perf pass cares about (DESIGN.md §8 L2):

* dot/convolution count — catches duplicated projections;
* while-loop count — should come only from Pallas grid loops;
* constant payload bytes — weights must be *parameters*, not baked-in
  constants (keeps artifacts small and checkpoint-swappable);
* fusion count — a coarse signal that XLA fused the elementwise chains.

Run: ``cd python && python -m compile.inspect_hlo [--dir ../artifacts]``
"""

import argparse
import json
import os
import re


def analyze_hlo_text(text: str) -> dict:
    """Count the interesting ops in one HLO module's text."""
    # Strip large literal payloads for the constant-bytes estimate first.
    const_bytes = 0
    for m in re.finditer(r"constant\(\{", text):
        # Find the matching payload crudely: scan to the closing brace
        # run; payload size ~ its text length / 8 chars per f32.
        start = m.end()
        depth = 1
        i = start
        while depth and i < len(text):
            if text[i] == "{":
                depth += 1
            elif text[i] == "}":
                depth -= 1
            i += 1
        const_bytes += (i - start) // 8 * 4

    counts = {
        "dots": len(re.findall(r"= \S+ dot\(", text)),
        "whiles": len(re.findall(r"= \S+ while\(", text)),
        "fusions": len(re.findall(r"= \S+ fusion\(", text)),
        "dynamic_update_slices": len(
            re.findall(r"dynamic-update-slice", text)),
        "parameters": len(re.findall(r"= \S+ parameter\(", text)),
        "const_payload_bytes": const_bytes,
        "bytes": len(text),
    }
    return counts


def analyze_dir(d: str) -> dict:
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    out = {}
    for art in manifest["artifacts"]:
        path = os.path.join(d, art["file"])
        out[art["name"]] = analyze_hlo_text(open(path).read())
        out[art["name"]]["kind"] = art["kind"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="../artifacts")
    args = ap.parse_args()
    rows = analyze_dir(args.dir)
    print(f"{'artifact':<44} {'dots':>5} {'while':>6} {'fus':>5} "
          f"{'dus':>4} {'const KiB':>10} {'text KiB':>9}")
    for name, c in sorted(rows.items()):
        print(
            f"{name:<44} {c['dots']:>5} {c['whiles']:>6} {c['fusions']:>5} "
            f"{c['dynamic_update_slices']:>4} "
            f"{c['const_payload_bytes']/1024:>10.1f} {c['bytes']/1024:>9.0f}"
        )


if __name__ == "__main__":
    main()
