"""Layer-2 JAX model: MLA projections + decode/prefill graphs.

Build-time only: everything here is traced once by ``aot.py`` and
shipped to the Rust runtime as HLO text.  The decode hot path calls the
Layer-1 Pallas kernels; prefill (compute-bound, run once per prompt)
uses the plain-jnp naive formulation, exactly as the paper prescribes
("naive kernels are preferred in training and prefill").

Weight layout: all per-layer weights are stacked on a leading layer
axis so the AOT'd functions take a fixed, small parameter list that the
Rust side loads from ``tiny_weights.npz``.
"""

import functools
from dataclasses import dataclass, fields

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import typhoon as tk
from .kernels.common import DEFAULT_KV_TILE

# ---------------------------------------------------------------------------
# Numerics building blocks
# ---------------------------------------------------------------------------

RMS_EPS = 1e-6


def rms_norm(x, w):
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + RMS_EPS) * w


def rope(x, positions, theta=10000.0):
    """Decoupled rotary embedding (rotate-half convention).

    x: [..., D_r]; positions: broadcastable to x.shape[:-1].
    """
    d_r = x.shape[-1]
    half = d_r // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


@dataclass
class MlaWeights:
    """Stacked transformer weights (leading axis = layer)."""

    embedding: jax.Array      # [V, d]
    w_qa: jax.Array           # [L, d, q_lora]
    q_norm: jax.Array         # [L, q_lora]
    w_qb: jax.Array           # [L, q_lora, H*D_qk]
    w_kva: jax.Array          # [L, d, D_l + D_r]
    kv_norm: jax.Array        # [L, D_l]
    w_kvb1: jax.Array         # [L, H, D_n, D_l]
    w_kvb2: jax.Array         # [L, H, D_v, D_l]
    w_o: jax.Array            # [L, H*D_v, d]
    attn_norm: jax.Array      # [L, d]
    mlp_norm: jax.Array       # [L, d]
    w_gate: jax.Array         # [L, d, ff]
    w_up: jax.Array           # [L, d, ff]
    w_down: jax.Array         # [L, ff, d]
    final_norm: jax.Array     # [d]

    def astuple(self):
        return tuple(getattr(self, f.name) for f in fields(self))

    @classmethod
    def field_names(cls):
        return [f.name for f in fields(cls)]

    @classmethod
    def fromtuple(cls, t):
        return cls(*t)


def init_weights(cfg: ModelConfig, seed: int = 0) -> MlaWeights:
    """Deterministic synthetic weights (scaled normal init)."""
    rng = np.random.default_rng(seed)
    L, d, H = cfg.n_layers, cfg.d_model, cfg.n_heads
    dqk, dv, dl, dr = cfg.d_qk, cfg.d_v, cfg.kv_lora_rank, cfg.d_rope
    dn, ql, ff, v = cfg.d_nope, cfg.q_lora_rank, cfg.d_ff, cfg.vocab_size

    def w(*shape, scale=None):
        scale = scale or (1.0 / np.sqrt(shape[-2] if len(shape) >= 2 else shape[-1]))
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    return MlaWeights(
        embedding=w(v, d, scale=0.02),
        w_qa=w(L, d, ql),
        q_norm=jnp.ones((L, ql), jnp.float32),
        w_qb=w(L, ql, H * dqk),
        w_kva=w(L, d, dl + dr),
        kv_norm=jnp.ones((L, dl), jnp.float32),
        w_kvb1=w(L, H, dn, dl, scale=1.0 / np.sqrt(dn)),
        w_kvb2=w(L, H, dv, dl, scale=1.0 / np.sqrt(dl)),
        w_o=w(L, H * dv, d),
        attn_norm=jnp.ones((L, d), jnp.float32),
        mlp_norm=jnp.ones((L, d), jnp.float32),
        w_gate=w(L, d, ff),
        w_up=w(L, d, ff),
        w_down=w(L, ff, d),
        final_norm=jnp.ones((d,), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Per-layer projection math (shared by decode and prefill)
# ---------------------------------------------------------------------------


def project_queries(cfg, wts: MlaWeights, i, x, positions):
    """x [..., d] -> (q_nope [..., H, D_n], q_rope [..., H, D_r])."""
    q = rms_norm(x @ wts.w_qa[i], wts.q_norm[i]) @ wts.w_qb[i]
    q = q.reshape(*x.shape[:-1], cfg.n_heads, cfg.d_qk)
    q_nope = q[..., : cfg.d_nope]
    q_rope = rope(q[..., cfg.d_nope:], positions[..., None], cfg.rope_theta)
    return q_nope, q_rope


def project_kv_latent(cfg, wts: MlaWeights, i, x, positions):
    """x [..., d] -> (ckv [..., D_l], krope [..., D_r]) cache entries."""
    kv = x @ wts.w_kva[i]
    ckv = rms_norm(kv[..., : cfg.kv_lora_rank], wts.kv_norm[i])
    krope = rope(kv[..., cfg.kv_lora_rank:], positions, cfg.rope_theta)
    return ckv, krope


def expand_latent(cfg, wts: MlaWeights, i, ckv, krope):
    """Latent -> uncompressed per-head K/V (the naive-form expansion).

    ckv [..., D_l], krope [..., D_r] ->
      k [..., H, D_qk], v [..., H, D_v].
    """
    k_nope = jnp.einsum("...d,hnd->...hn", ckv, wts.w_kvb1[i])
    v = jnp.einsum("...d,hvd->...hv", ckv, wts.w_kvb2[i])
    k_rope = jnp.broadcast_to(
        krope[..., None, :], (*k_nope.shape[:-1], cfg.d_rope))
    return jnp.concatenate([k_nope, k_rope], axis=-1), v


def mlp(wts: MlaWeights, i, x):
    return (jax.nn.silu(x @ wts.w_gate[i]) * (x @ wts.w_up[i])) @ wts.w_down[i]


# ---------------------------------------------------------------------------
# Decode step (the request-path graph, one token per sequence)
# ---------------------------------------------------------------------------


def decode_step(
    cfg: ModelConfig,
    wts: MlaWeights,
    variant: str,              # "typhoon" | "absorb" | "naive"
    tokens,                    # [B] int32
    lengths,                   # [B] int32 — non-shared tokens already cached
    shared_len,                # scalar int32 — valid shared prefix length
    shared_a,                  # typhoon/naive: K [Lyr,Ls,H,Dqk]; absorb: ckv [Lyr,Ls,Dl]
    shared_b,                  # typhoon/naive: V [Lyr,Ls,H,Dv]; absorb: krope [Lyr,Ls,Dr]
    ckv_cache,                 # [Lyr, B, Ln_max, D_l]
    krope_cache,               # [Lyr, B, Ln_max, D_r]
    *,
    kv_tile=DEFAULT_KV_TILE,
    interpret=True,
):
    """One decode iteration of the tiny MLA transformer.

    Computes this step's latent KV, scatters it into the (functional)
    cache at position ``lengths[b]``, runs the selected attention
    variant over shared+non-shared context, and greedily samples.

    Returns (next_tokens [B] i32, new_ckv [Lyr,B,D_l], new_krope
    [Lyr,B,D_r]).  The Rust coordinator owns the canonical cache and
    appends the returned entries itself.
    """
    b = tokens.shape[0]
    positions = shared_len + lengths               # [B]
    h = wts.embedding[tokens]                      # [B, d]
    new_ckvs, new_kropes = [], []

    for i in range(cfg.n_layers):
        x = rms_norm(h, wts.attn_norm[i])
        q_nope, q_rope = project_queries(cfg, wts, i, x, positions)
        ckv_new, krope_new = project_kv_latent(cfg, wts, i, x, positions)
        new_ckvs.append(ckv_new)
        new_kropes.append(krope_new)

        # Functional scatter of this step's entry at index lengths[b].
        upd = jax.vmap(
            lambda c, nk, idx: jax.lax.dynamic_update_slice(c, nk[None, :], (idx, 0)))
        ckv_i = upd(ckv_cache[i], ckv_new, lengths)
        krope_i = upd(krope_cache[i], krope_new, lengths)
        attn_lens = lengths + 1

        if variant == "typhoon":
            o = tk.typhoon_attention(
                q_nope, q_rope, shared_a[i], shared_b[i], shared_len,
                ckv_i, krope_i, attn_lens, wts.w_kvb1[i], wts.w_kvb2[i],
                kv_tile=kv_tile, interpret=interpret)
        elif variant == "absorb":
            o = tk.absorb_only_attention(
                q_nope, q_rope, shared_a[i], shared_b[i], shared_len,
                ckv_i, krope_i, attn_lens, wts.w_kvb1[i], wts.w_kvb2[i],
                kv_tile=kv_tile, interpret=interpret)
        elif variant == "naive":
            k_n, v_n = expand_latent(cfg, wts, i, ckv_i, krope_i)
            o = tk.naive_only_attention(
                q_nope, q_rope, shared_a[i], shared_b[i], shared_len,
                k_n, v_n, attn_lens, kv_tile=kv_tile, interpret=interpret)
        else:
            raise ValueError(f"unknown variant {variant!r}")

        h = h + o.reshape(b, -1) @ wts.w_o[i]
        h = h + mlp(wts, i, rms_norm(h, wts.mlp_norm[i]))

    logits = rms_norm(h, wts.final_norm) @ wts.embedding.T
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, jnp.stack(new_ckvs), jnp.stack(new_kropes)


# ---------------------------------------------------------------------------
# Prefill (compute path: plain-jnp naive attention, run once per prompt)
# ---------------------------------------------------------------------------


def _prefill_attention(q, k, v, mask):
    """q [B,S,H,Dqk], k/v [B,T,H,*], mask [B,1,S,T] -> [B,S,H,Dv]."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) * scale
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, v)


def prefill_shared(cfg: ModelConfig, wts: MlaWeights, tokens, shared_len,
                   out_len=None):
    """Prefill the shared prefix (a single sequence, batch of 1).

    tokens [Ls_max] int32 (padded), shared_len scalar int32.

    Returns per-layer caches, both latent and expanded:
      shared_ckv [Lyr, Ls, D_l], shared_krope [Lyr, Ls, D_r],
      shared_k [Lyr, Ls, H, D_qk], shared_v [Lyr, Ls, H, D_v].

    The expansion is free here: the naive prefill computes K/V anyway
    (paper §3.1 "the up-projection incurs no additional computational
    overhead" in prefill).
    """
    s = tokens.shape[0]
    out_len = out_len or s
    positions = jnp.arange(s, dtype=jnp.int32)
    valid = positions < shared_len
    h = wts.embedding[tokens][None]                # [1, S, d]
    pos_b = positions[None]
    causal = (positions[None, :] <= positions[:, None])[None, None]  # [1,1,S,S]
    mask = causal & valid[None, None, None, :]

    ckvs, kropes, ks, vs = [], [], [], []
    for i in range(cfg.n_layers):
        x = rms_norm(h, wts.attn_norm[i])
        q_nope, q_rope = project_queries(cfg, wts, i, x, pos_b)
        ckv, krope = project_kv_latent(cfg, wts, i, x, pos_b)
        k, v = expand_latent(cfg, wts, i, ckv, krope)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = _prefill_attention(q, k, v, mask)
        h = h + o.reshape(1, s, -1) @ wts.w_o[i]
        h = h + mlp(wts, i, rms_norm(h, wts.mlp_norm[i]))
        ckvs.append(ckv[0, :out_len])
        kropes.append(krope[0, :out_len])
        ks.append(k[0, :out_len])
        vs.append(v[0, :out_len])

    return (jnp.stack(ckvs), jnp.stack(kropes), jnp.stack(ks), jnp.stack(vs))


def prefill_requests(cfg: ModelConfig, wts: MlaWeights, tokens, q_lens,
                     shared_len, shared_k, shared_v, ckv_out_len=None):
    """Prefill a batch of non-shared question suffixes.

    tokens [B, Lq_max] int32 (padded), q_lens [B] int32,
    shared_k/shared_v [Lyr, Ls, H, *] expanded shared cache.

    Each request attends causally to its own tokens and fully to the
    valid shared prefix.  Returns:
      ckv_init [Lyr, B, Lq(or ckv_out_len), D_l],
      krope_init [Lyr, B, ..., D_r],
      first_tokens [B] int32 — greedy first decode token.
    """
    b, s = tokens.shape
    l_s = shared_k.shape[1]
    ckv_out_len = ckv_out_len or s
    positions = shared_len + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B,S]
    h = wts.embedding[tokens]                     # [B, S, d]

    own_causal = (jnp.arange(s)[None, :] <= jnp.arange(s)[:, None])
    own_valid = (jnp.arange(s)[None, :] < q_lens[:, None])           # [B,S]
    own_mask = own_causal[None, None] & own_valid[:, None, None, :]  # [B,1,S,S]
    shared_mask = jnp.broadcast_to(
        (jnp.arange(l_s) < shared_len)[None, None, None, :], (b, 1, s, l_s))
    mask = jnp.concatenate([shared_mask, own_mask], axis=-1)

    ckvs, kropes = [], []
    for i in range(cfg.n_layers):
        x = rms_norm(h, wts.attn_norm[i])
        q_nope, q_rope = project_queries(cfg, wts, i, x, positions)
        ckv, krope = project_kv_latent(cfg, wts, i, x, positions)
        k_own, v_own = expand_latent(cfg, wts, i, ckv, krope)
        k_sh = jnp.broadcast_to(shared_k[i][None], (b, *shared_k[i].shape))
        v_sh = jnp.broadcast_to(shared_v[i][None], (b, *shared_v[i].shape))
        k = jnp.concatenate([k_sh, k_own], axis=1)
        v = jnp.concatenate([v_sh, v_own], axis=1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = _prefill_attention(q, k, v, mask)
        h = h + o.reshape(b, s, -1) @ wts.w_o[i]
        h = h + mlp(wts, i, rms_norm(h, wts.mlp_norm[i]))
        ckvs.append(ckv[:, :ckv_out_len])
        kropes.append(krope[:, :ckv_out_len])

    # Logits at each request's last valid token.
    last_idx = jnp.maximum(q_lens - 1, 0)                            # [B]
    h_last = jnp.take_along_axis(h, last_idx[:, None, None], axis=1)[:, 0]
    logits = rms_norm(h_last, wts.final_norm) @ wts.embedding.T
    first_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(ckvs), jnp.stack(kropes), first_tokens


# ---------------------------------------------------------------------------
# Attention-only entry points (the kernel benchmark surface for Rust)
# ---------------------------------------------------------------------------


def attention_only(cfg: ModelConfig, variant: str):
    """Returns a pure attention function over explicit caches/weights.

    Used by aot.py to emit per-shape kernel artifacts that the Rust
    criterion benches drive directly (no transformer around them).
    """

    def typhoon_fn(q_nope, q_rope, shared_k, shared_v, shared_len,
                   ckv, krope, lengths, w_kvb1, w_kvb2):
        return (tk.typhoon_attention(
            q_nope, q_rope, shared_k, shared_v, shared_len[0],
            ckv, krope, lengths, w_kvb1, w_kvb2),)

    def absorb_fn(q_nope, q_rope, shared_ckv, shared_krope, shared_len,
                  ckv, krope, lengths, w_kvb1, w_kvb2):
        return (tk.absorb_only_attention(
            q_nope, q_rope, shared_ckv, shared_krope, shared_len[0],
            ckv, krope, lengths, w_kvb1, w_kvb2),)

    def naive_fn(q_nope, q_rope, shared_k, shared_v, shared_len,
                 k_n, v_n, lengths):
        return (tk.naive_only_attention(
            q_nope, q_rope, shared_k, shared_v, shared_len[0],
            k_n, v_n, lengths),)

    return {"typhoon": typhoon_fn, "absorb": absorb_fn, "naive": naive_fn}[variant]


def expand_fn(ckv, krope, w_kvb1, w_kvb2):
    """Latent -> uncompressed (K, V); the prefill-time shared-prefix
    expansion the Rust cache manager invokes for TyphoonMLA."""
    k_nope = jnp.einsum("...d,hnd->...hn", ckv, w_kvb1)
    v = jnp.einsum("...d,hvd->...hv", ckv, w_kvb2)
    d_r = krope.shape[-1]
    k_rope = jnp.broadcast_to(krope[..., None, :], (*k_nope.shape[:-1], d_r))
    return jnp.concatenate([k_nope, k_rope], axis=-1), v
