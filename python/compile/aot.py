"""AOT compiler: lower the L2 graphs to HLO text + manifest for Rust.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Emits, per (function x config x shape-bucket):
  * ``<name>.hlo.txt``  — HLO **text** (not a serialized HloModuleProto:
    jax >= 0.5 emits 64-bit instruction ids that the xla crate's
    xla_extension 0.5.1 rejects; the text parser reassigns ids).
  * an entry in ``manifest.json`` describing parameter/result shapes so
    the Rust runtime can marshal buffers without re-deriving them.
Plus ``tiny_weights.npz`` — the tiny e2e transformer's weights, loaded
by Rust via ``Literal::read_npz`` and passed as runtime parameters
(keeping them out of the HLO keeps artifacts small and lets Rust swap
checkpoints).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import CONFIGS, SIM, TINY

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(d):
    return {np.dtype(np.float32): "f32", np.dtype(np.int32): "s32"}[np.dtype(d)]


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.entries = []

    def emit(self, name, fn, in_specs, kind, **meta):
        """Trace fn over in_specs, write HLO text, record manifest entry."""
        # keep_unused: some graphs don't touch every weight (e.g. prefill
        # never reads final_norm); the Rust side passes the full bundle.
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *in_specs)
        outputs = [
            {"shape": list(a.shape), "dtype": _dtype_name(a.dtype)}
            for a in jax.tree_util.tree_leaves(out_avals)
        ]
        self.entries.append({
            "name": name,
            "file": fname,
            "kind": kind,
            "inputs": [
                {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                for s in in_specs
            ],
            "outputs": outputs,
            **meta,
        })
        print(f"  wrote {fname} ({len(text)/1024:.0f} KiB)")


# ---------------------------------------------------------------------------
# Artifact families
# ---------------------------------------------------------------------------


def emit_attention(em: Emitter, cfg, variant, b, ls, ln):
    """Pure attention-kernel artifact (the criterion bench surface)."""
    h, dn, dr, dv, dl = cfg.n_heads, cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank
    dqk = cfg.d_qk
    fn = M.attention_only(cfg, variant)
    common_q = [spec([b, h, dn]), spec([b, h, dr])]
    tail = [spec([1], I32), spec([b, ln, dl]), spec([b, ln, dr]), spec([b], I32),
            spec([h, dn, dl]), spec([h, dv, dl])]
    if variant == "typhoon":
        ins = common_q + [spec([ls, h, dqk]), spec([ls, h, dv])] + tail
    elif variant == "absorb":
        ins = common_q + [spec([ls, dl]), spec([ls, dr])] + tail
    elif variant == "naive":
        ins = common_q + [
            spec([ls, h, dqk]), spec([ls, h, dv]), spec([1], I32),
            spec([b, ln, h, dqk]), spec([b, ln, h, dv]), spec([b], I32)]
    name = f"attn_{variant}_{cfg.name}_b{b}_s{ls}_n{ln}"
    em.emit(name, fn, ins, "attention", variant=variant, config=cfg.name,
            dims={"b": b, "ls": ls, "ln": ln})


def emit_expand(em: Emitter, cfg, n):
    """Latent->uncompressed expansion (cache-manager utility)."""
    dl, dr, h, dv = cfg.kv_lora_rank, cfg.d_rope, cfg.n_heads, cfg.d_v
    ins = [spec([n, dl]), spec([n, dr]), spec([h, cfg.d_nope, dl]), spec([h, dv, dl])]
    em.emit(f"expand_{cfg.name}_n{n}", M.expand_fn, ins, "expand",
            config=cfg.name, dims={"n": n})


def emit_tiny_model(em: Emitter, cfg, b, ls, ln, lq):
    """Tiny e2e transformer: prefill_shared, prefill_requests, decode_step
    (one per variant).  Weights are runtime parameters in MlaWeights
    field order, appended after the data arguments."""
    lyr, h, dqk, dv, dl, dr = (cfg.n_layers, cfg.n_heads, cfg.d_qk, cfg.d_v,
                               cfg.kv_lora_rank, cfg.d_rope)
    wts0 = M.init_weights(cfg)
    w_specs = [spec(w.shape, w.dtype) for w in wts0.astuple()]
    w_names = M.MlaWeights.field_names()

    def with_weights(fn):
        def wrapped(*args):
            data, wt = args[: len(args) - len(w_specs)], args[len(args) - len(w_specs):]
            return fn(M.MlaWeights.fromtuple(wt), *data)
        return wrapped

    # prefill_shared(tokens [Ls], shared_len [1]) -> latent + expanded caches
    em.emit(
        f"prefill_shared_{cfg.name}_s{ls}",
        with_weights(lambda w, tokens, sl: M.prefill_shared(cfg, w, tokens, sl[0])),
        [spec([ls], I32), spec([1], I32)] + w_specs,
        "prefill_shared", config=cfg.name, dims={"ls": ls},
    )

    # prefill_requests(tokens [B,Lq], q_lens [B], shared_len [1],
    #                  shared_k [Lyr,Ls,H,Dqk], shared_v [Lyr,Ls,H,Dv])
    em.emit(
        f"prefill_req_{cfg.name}_b{b}_q{lq}_s{ls}",
        with_weights(lambda w, tokens, qlens, sl, sk, sv: M.prefill_requests(
            cfg, w, tokens, qlens, sl[0], sk, sv)),
        [spec([b, lq], I32), spec([b], I32), spec([1], I32),
         spec([lyr, ls, h, dqk]), spec([lyr, ls, h, dv])] + w_specs,
        "prefill_requests", config=cfg.name, dims={"b": b, "lq": lq, "ls": ls},
    )

    # decode_step per variant.
    for variant in ("typhoon", "absorb", "naive"):
        if variant == "absorb":
            sh = [spec([lyr, ls, dl]), spec([lyr, ls, dr])]
        else:
            sh = [spec([lyr, ls, h, dqk]), spec([lyr, ls, h, dv])]
        em.emit(
            f"model_{variant}_{cfg.name}_b{b}_s{ls}_n{ln}",
            with_weights(lambda w, tokens, lens, sl, sa, sb, ckv, krope,
                         _v=variant: M.decode_step(
                             cfg, w, _v, tokens, lens, sl[0], sa, sb, ckv, krope)),
            [spec([b], I32), spec([b], I32), spec([1], I32)] + sh
            + [spec([lyr, b, ln, dl]), spec([lyr, b, ln, dr])] + w_specs,
            "decode_step", variant=variant, config=cfg.name,
            dims={"b": b, "ls": ls, "ln": ln},
        )

    # Weights npz (shared by all tiny-model artifacts).
    npz_path = os.path.join(em.out_dir, f"{cfg.name}_weights.npz")
    np.savez(npz_path, **{n: np.asarray(w) for n, w in zip(w_names, wts0.astuple())})
    print(f"  wrote {os.path.basename(npz_path)}")
    return w_names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="default", choices=["default", "bench", "all"])
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out)

    # Attention-kernel artifacts (sim config; real CPU-PJRT execution).
    batches = [4, 16, 64, 128] if args.set == "default" else [4, 16, 64, 128, 256]
    print(f"[aot] attention kernels (sim config), b in {batches}")
    for b in batches:
        for variant in ("typhoon", "absorb", "naive"):
            emit_attention(em, SIM, variant, b=b, ls=1024, ln=256)
    emit_expand(em, SIM, n=1024)
    emit_expand(em, TINY, n=256)

    # Tiny end-to-end transformer.
    print("[aot] tiny e2e transformer")
    w_names = emit_tiny_model(em, TINY, b=8, ls=256, ln=128, lq=64)

    manifest = {
        "version": 1,
        "artifacts": em.entries,
        "weights": {"tiny": {"file": "tiny_weights.npz", "names": w_names}},
        "configs": {
            name: {
                "d_model": c.d_model, "n_heads": c.n_heads, "d_nope": c.d_nope,
                "d_rope": c.d_rope, "d_v": c.d_v, "kv_lora_rank": c.kv_lora_rank,
                "q_lora_rank": c.q_lora_rank, "n_layers": c.n_layers,
                "d_ff": c.d_ff, "vocab_size": c.vocab_size,
            }
            for name, c in CONFIGS.items()
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest.json with {len(em.entries)} artifacts")


if __name__ == "__main__":
    main()
