"""Build-time Python for TyphoonMLA: JAX model + Pallas kernels + AOT."""
