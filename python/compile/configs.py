"""Model configurations for TyphoonMLA.

Dimensions follow the paper's notation (Table 1):
  H      number of attention heads
  D_n    noPE head dim (per-head latent-decompressed key/query part)
  D_r    RoPE head dim (shared across heads in the key path)
  D_qk = D_n + D_r   full query/key head dim
  D_v    value head dim
  D_l    KV LoRA rank (latent dim of the compressed KV-cache)

The DeepSeek-v3 column of Table 1 follows from these:
  H*(D_qk+D_v)  = 128*320  = 40 Ki   (naive MAC/byte factor)
  H*(2*D_l+D_r) = 128*1088 = 136 Ki  (absorb MAC factor)
  D_l+D_r       = 576      = 0.5625 Ki (latent bytes/token)
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int          # H
    d_nope: int           # D_n
    d_rope: int           # D_r
    d_v: int              # D_v
    kv_lora_rank: int     # D_l
    q_lora_rank: int
    # Only used by the tiny end-to-end transformer:
    n_layers: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    rope_theta: float = 10000.0

    @property
    def d_qk(self) -> int:
        return self.d_nope + self.d_rope

    # --- Table 1 cost factors (per token per query, in MAC / words) ---
    def naive_factor(self) -> int:
        """MACs per (query token x context token): H*(D_qk + D_v)."""
        return self.n_heads * (self.d_qk + self.d_v)

    def absorb_factor(self) -> int:
        """MACs per (query token x context token): H*(2*D_l + D_r)."""
        return self.n_heads * (2 * self.kv_lora_rank + self.d_rope)

    def latent_words_per_token(self) -> int:
        """HBM words per cached token in latent form: D_l + D_r."""
        return self.kv_lora_rank + self.d_rope

    def uncompressed_words_per_token(self) -> int:
        """HBM words per cached token in uncompressed form: H*(D_qk + D_v)."""
        return self.n_heads * (self.d_qk + self.d_v)


# DeepSeek-v3 (DeepSeek-AI et al., 2024b) attention dims.
DEEPSEEK_V3 = ModelConfig(
    name="deepseek-v3",
    d_model=7168,
    n_heads=128,
    d_nope=128,
    d_rope=64,
    d_v=128,
    kv_lora_rank=512,
    q_lora_rank=1536,
)

# Kimi K2 (Bai et al., 2025): same head geometry, half the heads.
KIMI_K2 = ModelConfig(
    name="kimi-k2",
    d_model=7168,
    n_heads=64,
    d_nope=128,
    d_rope=64,
    d_v=128,
    kv_lora_rank=512,
    q_lora_rank=1536,
)

# Scaled-down geometry used for real CPU-PJRT execution (same aspect
# ratios as DeepSeek-v3: D_n = D_l/4, D_r = D_l/8, H*D_v = d_model/ ...).
SIM = ModelConfig(
    name="sim",
    d_model=512,
    n_heads=8,
    d_nope=64,
    d_rope=32,
    d_v=64,
    kv_lora_rank=128,
    q_lora_rank=192,
)

# Tiny end-to-end transformer (byte-level LM) for the serving example.
TINY = ModelConfig(
    name="tiny",
    d_model=256,
    n_heads=4,
    d_nope=32,
    d_rope=16,
    d_v=32,
    kv_lora_rank=64,
    q_lora_rank=96,
    n_layers=4,
    d_ff=512,
    vocab_size=256,
)

CONFIGS = {c.name: c for c in (DEEPSEEK_V3, KIMI_K2, SIM, TINY)}
