"""TyphoonMLA: the mixed naive-absorb decode attention kernel.

This is Algorithm 1 of the paper.  Given

* queries after the ``W_Qb`` projection, split into noPE/RoPE parts,
* the **shared prefix** cache in *uncompressed* (naive) form, and
* the **non-shared** suffix cache in *latent* (absorb) form,

it computes the naive flash kernel over the shared prefix (Stage 1 —
compute-efficient, stream reused across the whole batch), the absorb
flash kernel over the non-shared suffix (Stage 2 — bandwidth-efficient),
and merges the two partial softmax outputs exactly with the CombineLSE
epilogue.  The result is bit-for-bit the same attention as a monolithic
naive (or absorb) kernel over the concatenated context — no retraining,
no approximation.

The W_KVb1 (query absorption) and W_KVb2 (output up-projection) einsums
are taken as inputs/outputs of this module so the L2 model owns them;
their cost is reported separately in the paper's latency breakdown
(Fig. 4) and in our benches.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .absorb import absorb_batched_attention
from .common import DEFAULT_KV_TILE
from .naive import naive_shared_attention


def _combine_kernel(o1_ref, lse1_ref, o2_ref, lse2_ref, o_ref, lse_ref):
    """CombineLSE epilogue: merge two normalized partials via their LSEs.

    Element-wise over [B, H, D_v]; cost 2*B*H*D_v MACs + 2*B*H*D_v words,
    independent of context length (paper §3.2).
    """
    lse1 = lse1_ref[...]
    lse2 = lse2_ref[...]
    w1 = jax.nn.sigmoid(lse1 - lse2)[..., None]        # Z1/(Z1+Z2)
    o_ref[...] = (w1 * o1_ref[...] + (1.0 - w1) * o2_ref[...]).astype(o_ref.dtype)
    lse_ref[...] = jnp.logaddexp(lse1, lse2)


def combine_lse_kernel(o1, lse1, o2, lse2, *, interpret=True):
    """Pallas CombineLSE over full [B, H, D_v] partials.

    Single-block grid: the tensors are tiny (no KV dimension), so one
    VMEM-resident element-wise pass is the whole epilogue.
    """
    b, h, d_v = o1.shape
    o, lse = pl.pallas_call(
        _combine_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d_v), o1.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(o1, lse1, o2, lse2)
    return o, lse


def typhoon_attention(
    q_nope,        # [B, H, D_n]   queries, noPE part (pre-absorption)
    q_rope,        # [B, H, D_r]   queries, post-RoPE part
    shared_k,      # [L_s, H, D_qk]  uncompressed shared keys
    shared_v,      # [L_s, H, D_v]   uncompressed shared values
    shared_len,    # scalar int32
    ckv,           # [B, L_n, D_l]   non-shared noPE latent cache
    krope,         # [B, L_n, D_r]   non-shared RoPE key cache
    lengths,       # [B] int32       non-shared valid lengths
    w_kvb1,        # [H, D_n, D_l]   absorbed key up-projection
    w_kvb2,        # [H, D_v, D_l]   absorbed value up-projection
    *,
    kv_tile=DEFAULT_KV_TILE,
    b_tile=None,
    interpret=True,
):
    """Algorithm 1 — TyphoonMLA decode attention.

    Returns o [B, H, D_v]: exact MLA attention over the concatenated
    (shared ++ non-shared) context.
    """
    d_qk = q_nope.shape[-1] + q_rope.shape[-1]

    # Stage 1 (naive over the shared prefix): Q_K = [Q_N, Q_R].
    q_k = jnp.concatenate([q_nope, q_rope], axis=-1)   # [B, H, D_qk]
    o_n, lse_n = naive_shared_attention(
        q_k, shared_k, shared_v, shared_len,
        kv_tile=kv_tile, b_tile=b_tile, interpret=interpret)

    # Stage 2 (absorb over the non-shared suffix): Q_A = Q_N W_KVb1.
    q_lat = jnp.einsum("bhn,hnl->bhl", q_nope, w_kvb1)
    o_a_lat, lse_a = absorb_batched_attention(
        q_lat, q_rope, ckv, krope, lengths,
        kv_tile=kv_tile, d_qk=d_qk, interpret=interpret)
    # O_A = O_A_lat W_KVb2^T (output up-projection of the absorb branch).
    o_a = jnp.einsum("bhl,hvl->bhv", o_a_lat, w_kvb2)

    # CombineLSE epilogue.
    o, _ = combine_lse_kernel(o_n, lse_n, o_a, lse_a, interpret=interpret)
    return o


def absorb_only_attention(
    q_nope, q_rope, shared_ckv, shared_krope, shared_len,
    ckv, krope, lengths, w_kvb1, w_kvb2,
    *, kv_tile=DEFAULT_KV_TILE, interpret=True,
):
    """Absorb-only baseline (FlashMLA/CATLASS-analog) with the same
    shared/non-shared split: both parts in latent form.

    The TyphoonMLA fallback below the batch threshold B_theta executes
    exactly this path.
    """
    from .absorb import absorb_shared_attention

    d_qk = q_nope.shape[-1] + q_rope.shape[-1]
    q_lat = jnp.einsum("bhn,hnl->bhl", q_nope, w_kvb1)
    o_s_lat, lse_s = absorb_shared_attention(
        q_lat, q_rope, shared_ckv, shared_krope, shared_len,
        kv_tile=kv_tile, d_qk=d_qk, interpret=interpret)
    o_n_lat, lse_n = absorb_batched_attention(
        q_lat, q_rope, ckv, krope, lengths,
        kv_tile=kv_tile, d_qk=d_qk, interpret=interpret)
    o_lat, _ = combine_lse_kernel(o_s_lat, lse_s, o_n_lat, lse_n,
                                  interpret=interpret)
    return jnp.einsum("bhl,hvl->bhv", o_lat, w_kvb2)


def naive_only_attention(
    q_nope, q_rope, shared_k, shared_v, shared_len,
    k_n, v_n, lengths,
    *, kv_tile=DEFAULT_KV_TILE, b_tile=None, interpret=True,
):
    """Naive-only baseline (TorchNPU/FlashAttention-analog): both parts
    uncompressed.  The non-shared part is per-request (k_n/v_n carry a
    batch dim); the shared part is read once (prefix-aware naive, as in
    the paper's Table 1 naive HBM row).
    """
    from .naive import naive_batched_attention

    q_k = jnp.concatenate([q_nope, q_rope], axis=-1)
    o_s, lse_s = naive_shared_attention(
        q_k, shared_k, shared_v, shared_len,
        kv_tile=kv_tile, b_tile=b_tile, interpret=interpret)
    o_n, lse_n = naive_batched_attention(
        q_k, k_n, v_n, lengths, kv_tile=kv_tile, interpret=interpret)
    o, _ = combine_lse_kernel(o_s, lse_s, o_n, lse_n, interpret=interpret)
    return o
