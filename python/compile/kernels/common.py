"""Shared helpers for the Pallas MLA decode kernels.

All kernels in this package follow the flash-decoding contract: they
return an *(output, lse)* pair, where ``lse = m + log(sum exp(s - m))``
is the log-sum-exp of the (scaled, masked) attention scores.  Partial
attention outputs over disjoint KV ranges compose exactly via
:func:`combine_lse` — this is the paper's ``CombineLSE`` epilogue
(Algorithm 1, line 8).
"""

import jax
import jax.numpy as jnp

# Value used for masked-out score entries.  Finite (not -inf) so that a
# fully-masked tile still produces well-defined exp() results; 1e30 is far
# below any real score after scaling.
NEG_INF = -1e30

# Default KV-sequence tile.  128 matches both the paged-KV block size used
# by the coordinator and the TPU lane count, so one tile is one page and
# maps onto (8,128)-aligned MXU operands.
DEFAULT_KV_TILE = 128


def kv_tile_mask(t: jax.Array, tile: int, length: jax.Array) -> jax.Array:
    """Boolean [tile] mask: True for global positions < length.

    ``t`` is the KV-tile index of the current grid step; position ``i`` of
    the tile corresponds to global KV index ``t*tile + i``.
    """
    pos = t * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    return pos < length


def masked_scores(scores: jax.Array, mask: jax.Array) -> jax.Array:
    """Apply a [T] validity mask to a [..., T] score tile."""
    return jnp.where(mask[None, :], scores, NEG_INF)


def combine_lse(o1, lse1, o2, lse2):
    """Merge two normalized partial attention outputs via their LSEs.

    With ``o_i = S_i / Z_i`` and ``lse_i = log Z_i`` over disjoint KV
    ranges, the exact combined output is::

        o = (Z1*o1 + Z2*o2) / (Z1 + Z2)
          = sigmoid(lse1-lse2)*o1 + sigmoid(lse2-lse1)*o2

    and the combined LSE is ``logaddexp(lse1, lse2)``.  Purely
    element-wise: O(B*H*D_v) work, independent of KV length — the paper's
    argument for why the epilogue cost is negligible.
    """
    w1 = jax.nn.sigmoid(lse1 - lse2)[..., None]
    o = w1 * o1 + (1.0 - w1) * o2
    lse = jnp.logaddexp(lse1, lse2)
    return o, lse


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
