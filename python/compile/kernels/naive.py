"""Naive-formulation MLA decode attention as Pallas kernels.

The *naive* formulation decompresses the latent KV-cache into per-head
K/V tensors (standard MHA shapes) and runs flash attention over them.
Per (query x context-token) it costs ``H*(D_qk + D_v)`` MACs — 3.4x
fewer than absorb for DeepSeek-v3 — but must stream ``H*(D_qk + D_v)``
words per cached token from HBM, which only pays off when the stream is
reused across a large batch (the shared-prefix case).

Two kernels:

* :func:`naive_shared_attention` — the TyphoonMLA "Stage 1" kernel.  The
  K/V cache belongs to the *shared prefix* and carries no batch
  dimension; the grid is ordered ``(head, batch-tile, kv-tile)`` so one
  VMEM-resident K/V tile is reused by every query row in the batch
  tile — the TPU analog of Hydragen/relay-style prefix reuse done with
  threadblock scheduling on GPUs.

* :func:`naive_batched_attention` — per-request uncompressed K/V (used
  by the naive *baseline* for the non-shared suffix).

Both return ``(o, lse)`` and mask KV positions beyond the given length,
so callers can pad the cache to a tile multiple.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import DEFAULT_KV_TILE, NEG_INF, kv_tile_mask, masked_scores


def _flash_init(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _flash_update(scores, v, m_ref, l_ref, acc_ref):
    """One online-softmax step.

    scores: [R, T] masked score tile; v: [T, Dv];
    m_ref/l_ref: [R, 1] running max / denominator; acc_ref: [R, Dv]
    unnormalized numerator.
    """
    m_old = m_ref[...]                       # [R, 1]
    m_new = jnp.maximum(m_old, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_old - m_new)           # [R, 1]
    # Zero masked entries explicitly: in a fully-masked tile m_new is
    # still NEG_INF and exp(NEG_INF - NEG_INF) would be 1, not 0.
    p = jnp.where(scores > NEG_INF * 0.5, jnp.exp(scores - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _flash_finish(m_ref, l_ref, acc_ref, o_dtype):
    """Returns (o, lse) from the accumulated state.

    A fully-masked KV range yields l == 0; emit zeros and a NEG_INF lse
    so ``combine_lse`` ignores this branch entirely.
    """
    l = l_ref[...]
    safe_l = jnp.where(l > 0.0, l, 1.0)
    o = (acc_ref[...] / safe_l).astype(o_dtype)
    lse = jnp.where(l > 0.0, m_ref[...] + jnp.log(safe_l), NEG_INF)
    return o, lse


def _naive_shared_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                         m_ref, l_ref, acc_ref, *, kv_tile, n_kv):
    """Grid (H, nB, nT); T innermost so the online-softmax carry in the
    scratch refs is valid for a fixed (head, batch-tile)."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _():
        _flash_init(m_ref, l_ref, acc_ref)

    q = q_ref[:, 0, :]          # [Bblk, Dqk]
    k = k_ref[:, 0, :]          # [T, Dqk]
    v = v_ref[:, 0, :]          # [T, Dv]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = masked_scores(scores, kv_tile_mask(t, kv_tile, len_ref[0]))
    _flash_update(scores, v, m_ref, l_ref, acc_ref)

    @pl.when(t == n_kv - 1)
    def _():
        o, lse = _flash_finish(m_ref, l_ref, acc_ref, o_ref.dtype)
        o_ref[:, 0, :] = o
        lse_ref[...] = lse


def naive_shared_attention(q, k, v, length, *, kv_tile=DEFAULT_KV_TILE,
                           b_tile=None, interpret=True):
    """Flash attention of a batch of decode queries over a *shared* cache.

    Args:
      q: [B, H, D_qk] post-RoPE queries.
      k: [L_s, H, D_qk] uncompressed shared keys (L_s padded to kv_tile).
      v: [L_s, H, D_v] uncompressed shared values.
      length: scalar int32 — valid prefix length (<= L_s).

    Returns:
      o:   [B, H, D_v] normalized partial output.
      lse: [B, H] log-sum-exp of the scaled scores (f32).
    """
    b, h, d_qk = q.shape
    l_s, h_k, _ = k.shape
    assert h_k == h and l_s % kv_tile == 0, (k.shape, kv_tile)
    d_v = v.shape[-1]
    b_tile = b_tile or b
    assert b % b_tile == 0, (b, b_tile)
    n_kv = l_s // kv_tile
    grid = (h, b // b_tile, n_kv)

    length = jnp.asarray(length, jnp.int32).reshape((1,))
    kernel = functools.partial(_naive_shared_kernel, kv_tile=kv_tile, n_kv=n_kv)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda hh, bb, tt: (0,)),                    # length
            pl.BlockSpec((b_tile, 1, d_qk), lambda hh, bb, tt: (bb, hh, 0)),
            pl.BlockSpec((kv_tile, 1, d_qk), lambda hh, bb, tt: (tt, hh, 0)),
            pl.BlockSpec((kv_tile, 1, d_v), lambda hh, bb, tt: (tt, hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile, 1, d_v), lambda hh, bb, tt: (bb, hh, 0)),
            pl.BlockSpec((b_tile, 1), lambda hh, bb, tt: (bb, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d_v), q.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b_tile, 1), jnp.float32),
            pltpu.VMEM((b_tile, 1), jnp.float32),
            pltpu.VMEM((b_tile, d_v), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k, v)
    return o, lse


def _naive_batched_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                          m_ref, l_ref, acc_ref, *, kv_tile, n_kv):
    """Grid (B, H, nT): per-request uncompressed cache."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _():
        _flash_init(m_ref, l_ref, acc_ref)

    q = q_ref[0]                # [1, Dqk] (single batch x single head row)
    k = k_ref[0, :, 0, :]       # [T, Dqk]
    v = v_ref[0, :, 0, :]       # [T, Dv]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = masked_scores(scores, kv_tile_mask(t, kv_tile, len_ref[0]))
    _flash_update(scores, v, m_ref, l_ref, acc_ref)

    @pl.when(t == n_kv - 1)
    def _():
        o, lse = _flash_finish(m_ref, l_ref, acc_ref, o_ref.dtype)
        o_ref[0] = o
        lse_ref[...] = lse


def naive_batched_attention(q, k, v, lengths, *, kv_tile=DEFAULT_KV_TILE,
                            interpret=True):
    """Flash attention with a per-request uncompressed KV cache.

    Args:
      q: [B, H, D_qk]; k: [B, L_n, H, D_qk]; v: [B, L_n, H, D_v];
      lengths: [B] int32 per-request valid lengths.

    Returns: (o [B, H, D_v], lse [B, H]).
    """
    b, h, d_qk = q.shape
    _, l_n, _, d_v = v.shape
    assert l_n % kv_tile == 0, (l_n, kv_tile)
    n_kv = l_n // kv_tile
    grid = (b, h, n_kv)
    lengths = jnp.asarray(lengths, jnp.int32)

    kernel = functools.partial(_naive_batched_kernel, kv_tile=kv_tile, n_kv=n_kv)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, hh, tt: (bb,)),
            pl.BlockSpec((1, 1, d_qk), lambda bb, hh, tt: (bb, hh, 0)),
            pl.BlockSpec((1, kv_tile, 1, d_qk), lambda bb, hh, tt: (bb, tt, hh, 0)),
            pl.BlockSpec((1, kv_tile, 1, d_v), lambda bb, hh, tt: (bb, tt, hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d_v), lambda bb, hh, tt: (bb, hh, 0)),
            pl.BlockSpec((1, 1), lambda bb, hh, tt: (bb, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d_v), q.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d_v), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
    return o, lse
