"""Layer-1 Pallas kernels for TyphoonMLA (build-time only)."""
from . import absorb, common, naive, ref, typhoon  # noqa: F401
