"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is the direct, unfused formulation: materialize the full
score matrix, mask, softmax, contract.  Slow and memory-hungry, but
obviously correct — pytest compares every kernel against these.
"""

import jax
import jax.numpy as jnp

from .common import NEG_INF


def _lse(scores, axis=-1):
    return jax.scipy.special.logsumexp(scores, axis=axis)


def _masked_softmax_attn(scores, v, mask):
    """scores [..., L], v [..., L, D], mask [..., L] -> (o, lse)."""
    scores = jnp.where(mask, scores, NEG_INF)
    lse = _lse(scores)
    p = jnp.exp(scores - lse[..., None])
    o = jnp.einsum("...l,...ld->...d", p, v)
    return o, lse


def naive_shared_ref(q, k, v, length):
    """q [B,H,Dqk], k [Ls,H,Dqk], v [Ls,H,Dv], length scalar -> (o, lse)."""
    l_s = k.shape[0]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bhd,lhd->bhl", q, k) * scale
    mask = (jnp.arange(l_s) < length)[None, None, :]
    v_b = jnp.transpose(v, (1, 0, 2))[None]            # [1, H, Ls, Dv]
    return _masked_softmax_attn(scores, v_b, mask)


def naive_batched_ref(q, k, v, lengths):
    """q [B,H,Dqk], k [B,Ln,H,Dqk], v [B,Ln,H,Dv], lengths [B]."""
    l_n = k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bhd,blhd->bhl", q, k) * scale
    mask = (jnp.arange(l_n)[None, :] < lengths[:, None])[:, None, :]
    v_b = jnp.transpose(v, (0, 2, 1, 3))               # [B, H, Ln, Dv]
    return _masked_softmax_attn(scores, v_b, mask)


def absorb_batched_ref(q_lat, q_rope, ckv, krope, lengths, d_qk):
    """q_lat [B,H,Dl], q_rope [B,H,Dr], ckv [B,Ln,Dl], krope [B,Ln,Dr]."""
    l_n = ckv.shape[1]
    scale = 1.0 / (d_qk ** 0.5)
    scores = (
        jnp.einsum("bhl,bnl->bhn", q_lat, ckv)
        + jnp.einsum("bhr,bnr->bhn", q_rope, krope)
    ) * scale
    mask = (jnp.arange(l_n)[None, :] < lengths[:, None])[:, None, :]
    return _masked_softmax_attn(scores, ckv[:, None], mask)


def absorb_shared_ref(q_lat, q_rope, ckv, krope, length, d_qk):
    """Shared latent cache: ckv [Ls,Dl], krope [Ls,Dr]."""
    l_s = ckv.shape[0]
    scale = 1.0 / (d_qk ** 0.5)
    scores = (
        jnp.einsum("bhl,nl->bhn", q_lat, ckv)
        + jnp.einsum("bhr,nr->bhn", q_rope, krope)
    ) * scale
    mask = (jnp.arange(l_s) < length)[None, None, :]
    return _masked_softmax_attn(scores, ckv[None, None], mask)


def combine_lse_ref(o1, lse1, o2, lse2):
    w1 = jax.nn.sigmoid(lse1 - lse2)[..., None]
    return w1 * o1 + (1.0 - w1) * o2, jnp.logaddexp(lse1, lse2)


def mla_attention_monolithic_ref(q_nope, q_rope, ckv_full, krope_full,
                                 total_lengths, w_kvb1, w_kvb2):
    """Ground-truth MLA attention over the full (shared ++ non-shared)
    latent context, computed the naive way: decompress everything.

    q_nope [B,H,Dn], q_rope [B,H,Dr], ckv_full [B,L,Dl],
    krope_full [B,L,Dr], total_lengths [B],
    w_kvb1 [H,Dn,Dl], w_kvb2 [H,Dv,Dl]  -> o [B,H,Dv].

    Used to verify that typhoon == naive == absorb == this, i.e. the
    mathematical-equivalence claim of the paper.
    """
    # Decompress: k_nope [B,L,H,Dn], v [B,L,H,Dv].
    k_nope = jnp.einsum("bld,hnd->blhn", ckv_full, w_kvb1)
    v = jnp.einsum("bld,hvd->blhv", ckv_full, w_kvb2)
    l_total = ckv_full.shape[1]
    b, h, d_r = q_rope.shape
    k_rope = jnp.broadcast_to(krope_full[:, :, None, :], (b, l_total, h, d_r))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o, _ = naive_batched_ref(q, k, v, total_lengths)
    return o
