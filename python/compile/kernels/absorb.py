"""Absorb-formulation MLA decode attention as Pallas kernels.

The *absorb* formulation keeps the KV-cache in the compressed latent
space: per token only ``D_l + D_r`` words (the noPE latent ``c_kv`` and
the head-shared RoPE key ``k_r``).  The per-head up-projections are
*absorbed* into the query/output paths::

    q_lat[b,h] = q_nope[b,h] @ W_KVb1[h]          # [D_n] -> [D_l]
    s[b,h,i]   = (q_lat[b,h] . c_kv[i] + q_rope[b,h] . k_r[i]) / sqrt(D_qk)
    o_lat[b,h] = softmax(s) @ c_kv                # [D_l]
    o[b,h]     = o_lat[b,h] @ W_KVb2[h].T         # [D_l] -> [D_v]

This is FlashMLA's computation.  Score+PV cost per (query x token) is
``H*(2*D_l + D_r)`` MACs — 3.4x *more* than naive for DeepSeek-v3 — but
the HBM stream is ~70x smaller, which wins whenever attention is
memory-bound (no data reuse across the batch).

Two kernels, mirroring ``naive.py``:

* :func:`absorb_batched_attention` — per-request latent cache (the
  TyphoonMLA "Stage 2" kernel, and the absorb baseline's non-shared
  part).  Grid ``(batch, kv-tile)``; all heads processed per step since
  the latent cache is head-shared (single stream, H score rows).

* :func:`absorb_shared_attention` — latent cache of the shared prefix,
  no batch dimension (the absorb *baseline*'s shared part).  Queries are
  flattened to ``B*H`` rows over one latent stream.

Both take queries already absorbed (``q_lat``) — the W_KVb1/W_KVb2
einsums live in the L2 model (``model.py``) so their cost shows up as
the paper's ``W_KVb1-proj``/``W_KVb2-proj`` breakdown components — and
return ``(o_lat, lse)`` in latent space.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import DEFAULT_KV_TILE, kv_tile_mask, masked_scores
from .naive import _flash_finish, _flash_init, _flash_update


def _absorb_batched_kernel(len_ref, qlat_ref, qrope_ref, ckv_ref, krope_ref,
                           o_ref, lse_ref, m_ref, l_ref, acc_ref,
                           *, kv_tile, n_kv, d_qk):
    """Grid (B, nT): one request per outer step, latent cache tiles inner."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        _flash_init(m_ref, l_ref, acc_ref)

    q_lat = qlat_ref[0]         # [H, Dl]
    q_rope = qrope_ref[0]       # [H, Dr]
    ckv = ckv_ref[0]            # [T, Dl]
    krope = krope_ref[0]        # [T, Dr]
    # Scale by sqrt(D_qk): scores are mathematically the naive-formulation
    # scores, just computed in latent space (the absorption identity).
    scale = 1.0 / (d_qk ** 0.5)
    scores = (
        jnp.dot(q_lat, ckv.T, preferred_element_type=jnp.float32)
        + jnp.dot(q_rope, krope.T, preferred_element_type=jnp.float32)
    ) * scale                   # [H, T]
    scores = masked_scores(scores, kv_tile_mask(t, kv_tile, len_ref[0]))
    _flash_update(scores, ckv, m_ref, l_ref, acc_ref)

    @pl.when(t == n_kv - 1)
    def _():
        o, lse = _flash_finish(m_ref, l_ref, acc_ref, o_ref.dtype)
        o_ref[0] = o
        lse_ref[...] = lse.reshape(1, -1)   # [H,1] -> block (1, H)


def absorb_batched_attention(q_lat, q_rope, ckv, krope, lengths, *,
                             kv_tile=DEFAULT_KV_TILE, d_qk=None,
                             interpret=True):
    """Absorb-formulation flash decode over per-request latent caches.

    Args:
      q_lat:  [B, H, D_l]  absorbed queries (q_nope @ W_KVb1).
      q_rope: [B, H, D_r]  post-RoPE query tails.
      ckv:    [B, L_n, D_l] noPE latent cache (padded to kv_tile).
      krope:  [B, L_n, D_r] RoPE key cache (head-shared).
      lengths: [B] int32 valid lengths.
      d_qk: score scale dim (= D_n + D_r of the naive view). Defaults to
        D_l + D_r which is *wrong* for MLA — always pass the model's D_qk.

    Returns: (o_lat [B, H, D_l], lse [B, H]).
    """
    b, h, d_l = q_lat.shape
    _, l_n, _ = ckv.shape
    d_r = q_rope.shape[-1]
    assert l_n % kv_tile == 0, (l_n, kv_tile)
    d_qk = d_qk or (d_l + d_r)
    n_kv = l_n // kv_tile
    lengths = jnp.asarray(lengths, jnp.int32)

    kernel = functools.partial(
        _absorb_batched_kernel, kv_tile=kv_tile, n_kv=n_kv, d_qk=d_qk)
    o, lse = pl.pallas_call(
        kernel,
        grid=(b, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda bb, tt: (bb,)),
            pl.BlockSpec((1, h, d_l), lambda bb, tt: (bb, 0, 0)),
            pl.BlockSpec((1, h, d_r), lambda bb, tt: (bb, 0, 0)),
            pl.BlockSpec((1, kv_tile, d_l), lambda bb, tt: (bb, tt, 0)),
            pl.BlockSpec((1, kv_tile, d_r), lambda bb, tt: (bb, tt, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d_l), lambda bb, tt: (bb, 0, 0)),
            pl.BlockSpec((1, h), lambda bb, tt: (bb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d_l), q_lat.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d_l), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q_lat, q_rope, ckv, krope)
    return o, lse


def _absorb_shared_kernel(len_ref, qlat_ref, qrope_ref, ckv_ref, krope_ref,
                          o_ref, lse_ref, m_ref, l_ref, acc_ref,
                          *, kv_tile, n_kv, d_qk):
    """Grid (nR, nT): flattened B*H query rows over one shared latent stream."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        _flash_init(m_ref, l_ref, acc_ref)

    scale = 1.0 / (d_qk ** 0.5)
    scores = (
        jnp.dot(qlat_ref[...], ckv_ref[...].T, preferred_element_type=jnp.float32)
        + jnp.dot(qrope_ref[...], krope_ref[...].T, preferred_element_type=jnp.float32)
    ) * scale                   # [R, T]
    scores = masked_scores(scores, kv_tile_mask(t, kv_tile, len_ref[0]))
    _flash_update(scores, ckv_ref[...], m_ref, l_ref, acc_ref)

    @pl.when(t == n_kv - 1)
    def _():
        o, lse = _flash_finish(m_ref, l_ref, acc_ref, o_ref.dtype)
        o_ref[...] = o
        lse_ref[...] = lse[:, 0]


def absorb_shared_attention(q_lat, q_rope, ckv, krope, length, *,
                            kv_tile=DEFAULT_KV_TILE, r_tile=None,
                            d_qk=None, interpret=True):
    """Absorb-formulation flash decode over a *shared* latent cache.

    Args:
      q_lat:  [B, H, D_l]; q_rope: [B, H, D_r].
      ckv:    [L_s, D_l]; krope: [L_s, D_r] — shared prefix, latent form.
      length: scalar int32 valid prefix length.

    Returns: (o_lat [B, H, D_l], lse [B, H]).
    """
    b, h, d_l = q_lat.shape
    l_s, _ = ckv.shape
    d_r = q_rope.shape[-1]
    assert l_s % kv_tile == 0, (l_s, kv_tile)
    d_qk = d_qk or (d_l + d_r)
    n_kv = l_s // kv_tile
    rows = b * h
    r_tile = r_tile or rows
    assert rows % r_tile == 0

    length = jnp.asarray(length, jnp.int32).reshape((1,))
    q_lat2 = q_lat.reshape(rows, d_l)
    q_rope2 = q_rope.reshape(rows, d_r)

    kernel = functools.partial(
        _absorb_shared_kernel, kv_tile=kv_tile, n_kv=n_kv, d_qk=d_qk)
    o, lse = pl.pallas_call(
        kernel,
        grid=(rows // r_tile, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda rr, tt: (0,)),
            pl.BlockSpec((r_tile, d_l), lambda rr, tt: (rr, 0)),
            pl.BlockSpec((r_tile, d_r), lambda rr, tt: (rr, 0)),
            pl.BlockSpec((kv_tile, d_l), lambda rr, tt: (tt, 0)),
            pl.BlockSpec((kv_tile, d_r), lambda rr, tt: (tt, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r_tile, d_l), lambda rr, tt: (rr, 0)),
            pl.BlockSpec((r_tile,), lambda rr, tt: (rr,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d_l), q_lat.dtype),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((r_tile, 1), jnp.float32),
            pltpu.VMEM((r_tile, 1), jnp.float32),
            pltpu.VMEM((r_tile, d_l), jnp.float32),
        ],
        interpret=interpret,
    )(length, q_lat2, q_rope2, ckv, krope)
    return o.reshape(b, h, d_l), lse.reshape(b, h)
