"""Hypothesis sweeps: kernel-vs-ref over randomized shapes and lengths.

The deadline is disabled because interpret-mode Pallas runs the grid in
Python; examples are capped to keep the suite fast.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import absorb, naive, ref

SETTINGS = settings(max_examples=25, deadline=None,
                    suppress_health_check=[hypothesis.HealthCheck.too_slow])

TOL = dict(rtol=3e-5, atol=3e-5)


def _rand(data, *shape):
    # Deterministic values driven by hypothesis' entropy.
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@SETTINGS
@given(data=st.data())
def test_naive_shared_sweep(data):
    b = data.draw(st.integers(1, 8), label="b")
    h = data.draw(st.integers(1, 4), label="h")
    dqk = data.draw(st.sampled_from([8, 16, 24, 48, 96]), label="dqk")
    dv = data.draw(st.sampled_from([8, 16, 32, 64]), label="dv")
    tile = data.draw(st.sampled_from([8, 16, 32]), label="tile")
    n_tiles = data.draw(st.integers(1, 5), label="n_tiles")
    ls = tile * n_tiles
    length = data.draw(st.integers(0, ls), label="length")

    q = _rand(data, b, h, dqk)
    k = _rand(data, ls, h, dqk)
    v = _rand(data, ls, h, dv)
    o, lse = naive.naive_shared_attention(q, k, v, length, kv_tile=tile)
    if length == 0:
        assert np.all(np.asarray(o) == 0.0)
        return
    o_r, lse_r = ref.naive_shared_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r), **TOL)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), **TOL)


@SETTINGS
@given(data=st.data())
def test_naive_batched_sweep(data):
    b = data.draw(st.integers(1, 6), label="b")
    h = data.draw(st.integers(1, 3), label="h")
    dqk = data.draw(st.sampled_from([8, 24, 48]), label="dqk")
    dv = data.draw(st.sampled_from([8, 16, 32]), label="dv")
    tile = data.draw(st.sampled_from([8, 16]), label="tile")
    ln = tile * data.draw(st.integers(1, 4), label="n_tiles")
    seed = data.draw(st.integers(0, 2**31 - 1), label="lens_seed")
    lens = jnp.asarray(
        np.random.default_rng(seed).integers(1, ln + 1, size=b), jnp.int32)

    q = _rand(data, b, h, dqk)
    k = _rand(data, b, ln, h, dqk)
    v = _rand(data, b, ln, h, dv)
    o, lse = naive.naive_batched_attention(q, k, v, lens, kv_tile=tile)
    o_r, lse_r = ref.naive_batched_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r), **TOL)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), **TOL)


@SETTINGS
@given(data=st.data())
def test_absorb_batched_sweep(data):
    b = data.draw(st.integers(1, 6), label="b")
    h = data.draw(st.integers(1, 4), label="h")
    dl = data.draw(st.sampled_from([16, 32, 64, 128]), label="dl")
    dr = data.draw(st.sampled_from([8, 16, 32]), label="dr")
    d_qk = data.draw(st.sampled_from([24, 48, 96]), label="d_qk")
    tile = data.draw(st.sampled_from([8, 16, 32]), label="tile")
    ln = tile * data.draw(st.integers(1, 4), label="n_tiles")
    seed = data.draw(st.integers(0, 2**31 - 1), label="lens_seed")
    lens = jnp.asarray(
        np.random.default_rng(seed).integers(1, ln + 1, size=b), jnp.int32)

    q_lat = _rand(data, b, h, dl)
    q_rope = _rand(data, b, h, dr)
    ckv = _rand(data, b, ln, dl)
    krope = _rand(data, b, ln, dr)
    o, lse = absorb.absorb_batched_attention(
        q_lat, q_rope, ckv, krope, lens, kv_tile=tile, d_qk=d_qk)
    o_r, lse_r = ref.absorb_batched_ref(q_lat, q_rope, ckv, krope, lens, d_qk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r), **TOL)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), **TOL)


@SETTINGS
@given(data=st.data())
def test_absorb_shared_sweep(data):
    b = data.draw(st.integers(1, 6), label="b")
    h = data.draw(st.integers(1, 4), label="h")
    dl = data.draw(st.sampled_from([16, 64]), label="dl")
    dr = data.draw(st.sampled_from([8, 32]), label="dr")
    d_qk = data.draw(st.sampled_from([24, 96]), label="d_qk")
    tile = data.draw(st.sampled_from([8, 32]), label="tile")
    ls = tile * data.draw(st.integers(1, 4), label="n_tiles")
    length = data.draw(st.integers(1, ls), label="length")

    q_lat = _rand(data, b, h, dl)
    q_rope = _rand(data, b, h, dr)
    ckv = _rand(data, ls, dl)
    krope = _rand(data, ls, dr)
    o, lse = absorb.absorb_shared_attention(
        q_lat, q_rope, ckv, krope, length, kv_tile=tile, d_qk=d_qk)
    o_r, lse_r = ref.absorb_shared_ref(q_lat, q_rope, ckv, krope, length, d_qk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r), **TOL)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), **TOL)


@SETTINGS
@given(data=st.data())
def test_typhoon_equivalence_sweep(data):
    """Randomized version of the equivalence theorem test."""
    from compile.kernels import typhoon

    b = data.draw(st.integers(1, 4), label="b")
    h = data.draw(st.integers(1, 3), label="h")
    dn = data.draw(st.sampled_from([8, 16]), label="dn")
    dr = data.draw(st.sampled_from([8, 16]), label="dr")
    dv = data.draw(st.sampled_from([8, 16]), label="dv")
    dl = data.draw(st.sampled_from([16, 32]), label="dl")
    tile = 16
    sl = tile * data.draw(st.integers(1, 3), label="sl_tiles")
    ln = tile * data.draw(st.integers(1, 3), label="ln_tiles")
    seed = data.draw(st.integers(0, 2**31 - 1), label="lens_seed")
    lens = jnp.asarray(
        np.random.default_rng(seed).integers(1, ln + 1, size=b), jnp.int32)

    q_nope = _rand(data, b, h, dn)
    q_rope = _rand(data, b, h, dr)
    ckv_s = _rand(data, sl, dl)
    krope_s = _rand(data, sl, dr)
    ckv = _rand(data, b, ln, dl)
    krope = _rand(data, b, ln, dr)
    w1 = _rand(data, h, dn, dl) * 0.3
    w2 = _rand(data, h, dv, dl) * 0.3

    k_nope = jnp.einsum("ld,hnd->lhn", ckv_s, w1)
    v_sh = jnp.einsum("ld,hvd->lhv", ckv_s, w2)
    k_sh = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_s[:, None, :], (sl, h, dr))], axis=-1)

    o_t = typhoon.typhoon_attention(
        q_nope, q_rope, k_sh, v_sh, sl, ckv, krope, lens, w1, w2, kv_tile=tile)
    ckv_full = jnp.concatenate(
        [jnp.broadcast_to(ckv_s[None], (b, sl, dl)), ckv], axis=1)
    krope_full = jnp.concatenate(
        [jnp.broadcast_to(krope_s[None], (b, sl, dr)), krope], axis=1)
    o_m = ref.mla_attention_monolithic_ref(
        q_nope, q_rope, ckv_full, krope_full, sl + lens, w1, w2)
    np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_m),
                               rtol=5e-5, atol=5e-5)
