"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Fixed-shape parametrized cases cover the interesting boundaries
(single tile, many tiles, length == tile multiple, length 1, ragged
batches); hypothesis sweeps randomize shapes/lengths more broadly in
``test_hypothesis.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import absorb, naive, ref, typhoon
from compile.kernels.common import combine_lse

from .conftest import randf

TOL = dict(rtol=2e-5, atol=2e-5)


def assert_close(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **{**TOL, **kw})


@pytest.mark.parametrize(
    "b,h,dqk,dv,ls,length,tile",
    [
        (1, 1, 8, 8, 16, 16, 16),     # single tile, full length
        (4, 3, 24, 16, 64, 50, 16),   # ragged tail in last tile
        (8, 2, 24, 16, 64, 1, 16),    # single valid token
        (2, 4, 48, 32, 128, 64, 32),  # length on a tile boundary
        (16, 8, 96, 64, 256, 200, 128),  # sim-config-like dims
    ],
)
def test_naive_shared_vs_ref(rng, b, h, dqk, dv, ls, length, tile):
    q = randf(rng, b, h, dqk)
    k = randf(rng, ls, h, dqk)
    v = randf(rng, ls, h, dv)
    o, lse = naive.naive_shared_attention(q, k, v, length, kv_tile=tile)
    o_r, lse_r = ref.naive_shared_ref(q, k, v, length)
    assert_close(o, o_r)
    assert_close(lse, lse_r)


@pytest.mark.parametrize("b_tile", [1, 2, 4])
def test_naive_shared_batch_tiling(rng, b_tile):
    """Tiling the batch dimension must not change results."""
    q = randf(rng, 4, 2, 24)
    k = randf(rng, 32, 2, 24)
    v = randf(rng, 32, 2, 16)
    o_full, lse_full = naive.naive_shared_attention(q, k, v, 30, kv_tile=16)
    o_t, lse_t = naive.naive_shared_attention(q, k, v, 30, kv_tile=16, b_tile=b_tile)
    assert_close(o_t, o_full)
    assert_close(lse_t, lse_full)


@pytest.mark.parametrize(
    "b,h,dqk,dv,ln,tile",
    [
        (1, 1, 8, 8, 16, 16),
        (4, 3, 24, 16, 64, 16),
        (6, 2, 48, 32, 128, 32),
    ],
)
def test_naive_batched_vs_ref(rng, b, h, dqk, dv, ln, tile):
    q = randf(rng, b, h, dqk)
    k = randf(rng, b, ln, h, dqk)
    v = randf(rng, b, ln, h, dv)
    lengths = jnp.asarray(rng.integers(1, ln + 1, size=b), jnp.int32)
    o, lse = naive.naive_batched_attention(q, k, v, lengths, kv_tile=tile)
    o_r, lse_r = ref.naive_batched_ref(q, k, v, lengths)
    assert_close(o, o_r)
    assert_close(lse, lse_r)


@pytest.mark.parametrize(
    "b,h,dl,dr,ln,tile",
    [
        (1, 1, 16, 8, 16, 16),
        (4, 3, 32, 8, 64, 16),
        (8, 8, 128, 32, 256, 128),   # sim-config dims
    ],
)
def test_absorb_batched_vs_ref(rng, b, h, dl, dr, ln, tile):
    d_qk = 24
    q_lat = randf(rng, b, h, dl)
    q_rope = randf(rng, b, h, dr)
    ckv = randf(rng, b, ln, dl)
    krope = randf(rng, b, ln, dr)
    lengths = jnp.asarray(rng.integers(1, ln + 1, size=b), jnp.int32)
    o, lse = absorb.absorb_batched_attention(
        q_lat, q_rope, ckv, krope, lengths, kv_tile=tile, d_qk=d_qk)
    o_r, lse_r = ref.absorb_batched_ref(q_lat, q_rope, ckv, krope, lengths, d_qk)
    assert_close(o, o_r)
    assert_close(lse, lse_r)


@pytest.mark.parametrize(
    "b,h,dl,dr,ls,length,tile",
    [
        (2, 2, 16, 8, 32, 20, 16),
        (4, 4, 64, 16, 128, 128, 32),
        (8, 8, 128, 32, 512, 300, 128),
    ],
)
def test_absorb_shared_vs_ref(rng, b, h, dl, dr, ls, length, tile):
    d_qk = 40
    q_lat = randf(rng, b, h, dl)
    q_rope = randf(rng, b, h, dr)
    ckv = randf(rng, ls, dl)
    krope = randf(rng, ls, dr)
    o, lse = absorb.absorb_shared_attention(
        q_lat, q_rope, ckv, krope, length, kv_tile=tile, d_qk=d_qk)
    o_r, lse_r = ref.absorb_shared_ref(q_lat, q_rope, ckv, krope, length, d_qk)
    assert_close(o, o_r)
    assert_close(lse, lse_r)


def test_absorb_shared_row_tiling(rng):
    q_lat = randf(rng, 4, 2, 16)
    q_rope = randf(rng, 4, 2, 8)
    ckv = randf(rng, 32, 16)
    krope = randf(rng, 32, 8)
    o_full, lse_full = absorb.absorb_shared_attention(
        q_lat, q_rope, ckv, krope, 32, kv_tile=16, d_qk=24)
    o_t, lse_t = absorb.absorb_shared_attention(
        q_lat, q_rope, ckv, krope, 32, kv_tile=16, d_qk=24, r_tile=2)
    assert_close(o_t, o_full)
    assert_close(lse_t, lse_full)


class TestCombineLSE:
    def test_combine_kernel_vs_ref(self, rng):
        o1, o2 = randf(rng, 4, 3, 16), randf(rng, 4, 3, 16)
        lse1, lse2 = randf(rng, 4, 3), randf(rng, 4, 3)
        o, lse = typhoon.combine_lse_kernel(o1, lse1, o2, lse2)
        o_r, lse_r = ref.combine_lse_ref(o1, lse1, o2, lse2)
        assert_close(o, o_r)
        assert_close(lse, lse_r)

    def test_combine_matches_joint_softmax(self, rng):
        """Combining partials over disjoint KV ranges == one softmax."""
        q = randf(rng, 2, 2, 24)
        k = randf(rng, 64, 2, 24)
        v = randf(rng, 64, 2, 16)
        o_full, lse_full = ref.naive_shared_ref(q, k, v, 64)
        o1, lse1 = ref.naive_shared_ref(q, k[:32], v[:32], 32)
        o2, lse2 = ref.naive_shared_ref(q, k[32:], v[32:], 32)
        o_c, lse_c = combine_lse(o1, lse1, o2, lse2)
        assert_close(o_c, o_full)
        assert_close(lse_c, lse_full)

    def test_combine_is_commutative(self, rng):
        o1, o2 = randf(rng, 2, 2, 8), randf(rng, 2, 2, 8)
        lse1, lse2 = randf(rng, 2, 2), randf(rng, 2, 2)
        oa, la = combine_lse(o1, lse1, o2, lse2)
        ob, lb = combine_lse(o2, lse2, o1, lse1)
        assert_close(oa, ob)
        assert_close(la, lb)

    def test_combine_associative_three_way(self, rng):
        """((1+2)+3) == (1+(2+3)) over a real split attention."""
        q = randf(rng, 2, 1, 16)
        k = randf(rng, 48, 1, 16)
        v = randf(rng, 48, 1, 8)
        parts = [ref.naive_shared_ref(q, k[i:i + 16], v[i:i + 16], 16)
                 for i in (0, 16, 32)]
        o_l, l_l = combine_lse(*combine_lse(*parts[0], *parts[1]), *parts[2])
        o_r_, l_r_ = combine_lse(*parts[0], *combine_lse(*parts[1], *parts[2]))
        assert_close(o_l, o_r_)
        o_full, _ = ref.naive_shared_ref(q, k, v, 48)
        assert_close(o_l, o_full)

    def test_combine_ignores_empty_branch(self, rng):
        """A fully-masked (length-0) branch must be a no-op in combine."""
        q = randf(rng, 2, 2, 24)
        k = randf(rng, 32, 2, 24)
        v = randf(rng, 32, 2, 16)
        o_full, lse_full = naive.naive_shared_attention(q, k, v, 32, kv_tile=16)
        o_empty, lse_empty = naive.naive_shared_attention(q, k, v, 0, kv_tile=16)
        assert np.all(np.asarray(o_empty) == 0.0)
        o_c, lse_c = combine_lse(o_full, lse_full, o_empty, lse_empty)
        assert_close(o_c, o_full)
        assert_close(lse_c, lse_full)


def test_lse_is_finite_and_ordered(rng):
    """LSE must grow monotonically with context length (more mass)."""
    q = randf(rng, 1, 1, 16, scale=0.1)
    k = jnp.abs(randf(rng, 64, 1, 16, scale=0.1))
    v = randf(rng, 64, 1, 8)
    q = jnp.abs(q)
    lses = []
    for length in (16, 32, 48, 64):
        _, lse = naive.naive_shared_attention(q, k, v, length, kv_tile=16)
        lses.append(float(lse[0, 0]))
    assert all(np.isfinite(lses))
    assert lses == sorted(lses), lses  # positive scores => monotone lse
