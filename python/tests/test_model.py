"""L2 model tests: decode-step variant agreement, prefill/decode
pipeline consistency, and unit properties of the projection math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import TINY

from .conftest import randf

TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(TINY, seed=7)


def test_rms_norm_properties(rng):
    x = randf(rng, 4, 16) * 10.0
    w = jnp.ones(16)
    y = M.rms_norm(x, w)
    # Unit RMS after normalization.
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)
    # Scale equivariance: rms_norm(a*x) == rms_norm(x).
    y2 = M.rms_norm(3.5 * x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), **TOL)


def test_rope_preserves_norm_and_relative_phase(rng):
    x = randf(rng, 2, 8)
    pos = jnp.array([3, 11])
    y = M.rope(x, pos)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-5,
    )
    # Relative property: <rope(q,m), rope(k,n)> depends only on m-n.
    q = randf(rng, 8)
    k = randf(rng, 8)
    def dot(m, n):
        return float(M.rope(q[None], jnp.array([m]))[0]
                     @ M.rope(k[None], jnp.array([n]))[0])
    np.testing.assert_allclose(dot(5, 2), dot(10, 7), rtol=1e-4)


def test_rope_zero_position_is_identity(rng):
    x = randf(rng, 3, 8)
    y = M.rope(x, jnp.zeros(3, jnp.int32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_expand_latent_matches_einsum(rng, weights):
    ckv = randf(rng, 5, TINY.kv_lora_rank)
    krope = randf(rng, 5, TINY.d_rope)
    k, v = M.expand_latent(TINY, weights, 0, ckv, krope)
    assert k.shape == (5, TINY.n_heads, TINY.d_qk)
    assert v.shape == (5, TINY.n_heads, TINY.d_v)
    # RoPE tail of K is the broadcast krope.
    np.testing.assert_allclose(
        np.asarray(k[:, 0, TINY.d_nope:]), np.asarray(krope), **TOL)
    np.testing.assert_allclose(
        np.asarray(k[:, 2, TINY.d_nope:]), np.asarray(krope), **TOL)


class TestDecodePipeline:
    """prefill_shared -> prefill_requests -> decode_step, all variants."""

    @pytest.fixture(scope="class")
    def pipeline(self):
        cfg = TINY
        wts = M.init_weights(cfg, seed=7)
        rng = np.random.default_rng(3)
        ls, b, lq, ln = 64, 4, 16, 32
        shared_tokens = jnp.asarray(rng.integers(1, 256, ls), jnp.int32)
        ckv_s, krope_s, k_s, v_s = M.prefill_shared(cfg, wts, shared_tokens, ls)
        req_tokens = jnp.asarray(rng.integers(1, 256, (b, lq)), jnp.int32)
        q_lens = jnp.asarray([16, 9, 3, 12], jnp.int32)
        ckv0, krope0, first = M.prefill_requests(
            cfg, wts, req_tokens, q_lens, ls, k_s, v_s)
        # Scatter into padded caches [Lyr, B, Ln, D].
        lyr = cfg.n_layers
        ckv = jnp.zeros((lyr, b, ln, cfg.kv_lora_rank))
        krope = jnp.zeros((lyr, b, ln, cfg.d_rope))
        ckv = ckv.at[:, :, :lq].set(ckv0)
        krope = krope.at[:, :, :lq].set(krope0)
        return dict(cfg=cfg, wts=wts, ls=ls, b=b, lq=lq, ln=ln,
                    shared_tokens=shared_tokens, q_lens=q_lens,
                    ckv_s=ckv_s, krope_s=krope_s, k_s=k_s, v_s=v_s,
                    ckv=ckv, krope=krope, first=first)

    def test_shared_expansion_consistent(self, pipeline):
        p = pipeline
        k, v = M.expand_latent(
            p["cfg"], p["wts"], 0, p["ckv_s"][0], p["krope_s"][0])
        np.testing.assert_allclose(np.asarray(k), np.asarray(p["k_s"][0]), **TOL)
        np.testing.assert_allclose(np.asarray(v), np.asarray(p["v_s"][0]), **TOL)

    def test_first_tokens_valid(self, pipeline):
        first = np.asarray(pipeline["first"])
        assert first.shape == (4,)
        assert ((0 <= first) & (first < TINY.vocab_size)).all()

    @pytest.mark.parametrize("steps", [3])
    def test_variants_generate_identical_tokens(self, pipeline, steps):
        p = pipeline
        cfg, wts = p["cfg"], p["wts"]
        results = {}
        for variant in ("typhoon", "absorb", "naive"):
            if variant == "absorb":
                sa, sb = p["ckv_s"], p["krope_s"]
            else:
                sa, sb = p["k_s"], p["v_s"]
            tokens = p["first"]
            lengths = p["q_lens"]
            ckv, krope = p["ckv"], p["krope"]
            history = [np.asarray(tokens)]
            for _ in range(steps):
                nxt, new_ckv, new_krope = M.decode_step(
                    cfg, wts, variant, tokens, lengths, p["ls"],
                    sa, sb, ckv, krope, kv_tile=16)
                # Host-side scatter (mirrors the Rust engine).
                idx = np.asarray(lengths)
                ckv_np = np.array(ckv)
                krope_np = np.array(krope)
                for l in range(cfg.n_layers):
                    for bb in range(p["b"]):
                        ckv_np[l, bb, idx[bb]] = np.asarray(new_ckv)[l, bb]
                        krope_np[l, bb, idx[bb]] = np.asarray(new_krope)[l, bb]
                ckv, krope = jnp.asarray(ckv_np), jnp.asarray(krope_np)
                lengths = lengths + 1
                tokens = nxt
                history.append(np.asarray(nxt))
            results[variant] = np.stack(history)
        np.testing.assert_array_equal(results["typhoon"], results["absorb"])
        np.testing.assert_array_equal(results["typhoon"], results["naive"])

    def test_decode_against_full_context_reference(self, pipeline):
        """One decode step must match a from-scratch full-context forward
        pass (prefill+decode incremental consistency)."""
        p = pipeline
        cfg, wts = p["cfg"], p["wts"]
        b = p["b"]
        # Incremental path.
        nxt, _, _ = M.decode_step(
            cfg, wts, "typhoon", p["first"], p["q_lens"], p["ls"],
            p["k_s"], p["v_s"], p["ckv"], p["krope"], kv_tile=16)
        # Reference: rerun prefill_requests with each question extended by
        # its first generated token; its "first token" output is then the
        # second generated token — which must equal nxt.
        rng = np.random.default_rng(3)
        _ = rng.integers(1, 256, p["ls"])  # consume shared draw
        req_tokens = np.asarray(
            jnp.asarray(rng.integers(1, 256, (b, p["lq"])), jnp.int32))
        q_lens = np.asarray(p["q_lens"])
        ext = np.zeros((b, p["lq"] + 1), np.int32)
        ext[:, : p["lq"]] = req_tokens
        for bb in range(b):
            ext[bb, q_lens[bb]] = int(np.asarray(p["first"])[bb])
        _, _, second = M.prefill_requests(
            cfg, wts, jnp.asarray(ext), jnp.asarray(q_lens + 1), p["ls"],
            p["k_s"], p["v_s"])
        np.testing.assert_array_equal(np.asarray(nxt), np.asarray(second))
