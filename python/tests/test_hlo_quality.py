"""L2 perf-quality gates over the AOT artifacts + L1 structural gates.

Skipped when artifacts have not been built (`make artifacts`)."""

import os

import pytest

from compile.configs import DEEPSEEK_V3, KIMI_K2, SIM, TINY
from compile.inspect_hlo import analyze_dir
from compile.tuning import (VMEM_BUDGET, absorb_batched_footprint,
                            naive_shared_footprint)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built",
)


@pytest.fixture(scope="module")
def analysis():
    return analyze_dir(ART_DIR)


def test_no_weight_constants_in_model_artifacts(analysis):
    """Weights must be parameters, not baked constants: const payload of
    every artifact stays tiny (< 64 KiB) even though the tiny model has
    ~2M parameters (~8 MB)."""
    for name, c in analysis.items():
        assert c["const_payload_bytes"] < 64 * 1024, (name, c)


def test_decode_step_dot_budget(analysis):
    """No duplicated projections: per layer the decode step needs at
    most ~16 contractions (q down/up, kv down, absorb or expand paths,
    two attention stages at 3 dots each, W_KVb1/2, output, 3 MLP) plus
    the logits matmul."""
    lyr = TINY.n_layers
    for name, c in analysis.items():
        if c["kind"] != "decode_step":
            continue
        budget = lyr * 16 + 2
        assert c["dots"] <= budget, f"{name}: {c['dots']} dots > {budget}"
        assert c["dots"] >= lyr * 6, f"{name}: implausibly few dots"


def test_attention_artifacts_have_no_while_loops(analysis):
    """Pallas interpret-mode grids lower to unrolled/fused HLO with
    dynamic-update-slices, not while loops; their presence would signal
    an accidental scan/recompute."""
    for name, c in analysis.items():
        if c["kind"] == "attention":
            assert c["whiles"] == 0, (name, c)


def test_attention_dot_counts_by_variant(analysis):
    """naive = 2 dots/stage x 2 stages; absorb adds score-split dots and
    the two projection einsums; typhoon sits in between.  Exact values
    pin the lowering so regressions (e.g. XLA splitting a dot) surface."""
    for name, c in analysis.items():
        if c["kind"] != "attention":
            continue
        if "naive" in name:
            assert c["dots"] == 4, (name, c["dots"])
        elif "absorb" in name:
            assert c["dots"] == 8, (name, c["dots"])
        elif "typhoon" in name:
            assert c["dots"] == 7, (name, c["dots"])


def test_vmem_budgets_at_paper_scale():
    """Every kernel's per-step working set fits VMEM at DeepSeek-v3 and
    Kimi K2 dimensions with the default (128) KV tile."""
    for cfg in (SIM, DEEPSEEK_V3, KIMI_K2):
        for kv_tile in (128, 256):
            n = naive_shared_footprint(cfg, b_tile=128, kv_tile=kv_tile)
            a = absorb_batched_footprint(cfg, kv_tile=kv_tile)
            assert n.vmem_bytes < VMEM_BUDGET, n.name
            assert a.vmem_bytes < VMEM_BUDGET, a.name


def test_mxu_alignment_at_paper_scale():
    """With kv_tile=128, every contraction in both kernels is
    MXU-aligned for DeepSeek-v3/Kimi K2 (D_qk=192, D_v=128, D_l=512)."""
    for cfg in (DEEPSEEK_V3, KIMI_K2):
        n = naive_shared_footprint(cfg, b_tile=128, kv_tile=128)
        a = absorb_batched_footprint(cfg, kv_tile=128)
        assert all(n.mxu_aligned()), n.name
        assert all(a.mxu_aligned()), a.name
