"""The paper's central mathematical claim: TyphoonMLA == naive == absorb.

All three attention formulations (and the below-threshold fallback) must
produce identical outputs over the same logical context.  We verify each
against the monolithic decompress-everything oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, typhoon

from .conftest import randf

TOL = dict(rtol=3e-5, atol=3e-5)


def make_problem(rng, b=4, h=3, dn=16, dr=8, dv=16, dl=32, sl=48, ln=32,
                 tile=16):
    lens = jnp.asarray(rng.integers(1, ln + 1, size=b), jnp.int32)
    p = dict(
        b=b, h=h, dn=dn, dr=dr, dv=dv, dl=dl, sl=sl, ln=ln, tile=tile,
        q_nope=randf(rng, b, h, dn),
        q_rope=randf(rng, b, h, dr),
        ckv_shared=randf(rng, sl, dl),
        krope_shared=randf(rng, sl, dr),
        ckv=randf(rng, b, ln, dl),
        krope=randf(rng, b, ln, dr),
        lens=lens,
        w_kvb1=randf(rng, h, dn, dl, scale=0.3),
        w_kvb2=randf(rng, h, dv, dl, scale=0.3),
    )
    # Uncompressed (naive-form) shared cache.
    k_nope = jnp.einsum("ld,hnd->lhn", p["ckv_shared"], p["w_kvb1"])
    p["v_shared"] = jnp.einsum("ld,hvd->lhv", p["ckv_shared"], p["w_kvb2"])
    p["k_shared"] = jnp.concatenate(
        [k_nope, jnp.broadcast_to(p["krope_shared"][:, None, :], (sl, h, dr))],
        axis=-1)
    # Uncompressed non-shared cache (for the naive baseline).
    k_nope_n = jnp.einsum("bld,hnd->blhn", p["ckv"], p["w_kvb1"])
    p["v_n"] = jnp.einsum("bld,hvd->blhv", p["ckv"], p["w_kvb2"])
    p["k_n"] = jnp.concatenate(
        [k_nope_n, jnp.broadcast_to(p["krope"][:, :, None, :], (b, ln, h, dr))],
        axis=-1)
    return p


def monolithic(p):
    b = p["b"]
    ckv_full = jnp.concatenate(
        [jnp.broadcast_to(p["ckv_shared"][None], (b, p["sl"], p["dl"])), p["ckv"]],
        axis=1)
    krope_full = jnp.concatenate(
        [jnp.broadcast_to(p["krope_shared"][None], (b, p["sl"], p["dr"])), p["krope"]],
        axis=1)
    return ref.mla_attention_monolithic_ref(
        p["q_nope"], p["q_rope"], ckv_full, krope_full,
        p["sl"] + p["lens"], p["w_kvb1"], p["w_kvb2"])


@pytest.fixture
def problem(rng):
    return make_problem(rng)


def test_typhoon_equals_monolithic(problem):
    p = problem
    o = typhoon.typhoon_attention(
        p["q_nope"], p["q_rope"], p["k_shared"], p["v_shared"], p["sl"],
        p["ckv"], p["krope"], p["lens"], p["w_kvb1"], p["w_kvb2"],
        kv_tile=p["tile"])
    np.testing.assert_allclose(np.asarray(o), np.asarray(monolithic(p)), **TOL)


def test_absorb_only_equals_monolithic(problem):
    p = problem
    o = typhoon.absorb_only_attention(
        p["q_nope"], p["q_rope"], p["ckv_shared"], p["krope_shared"], p["sl"],
        p["ckv"], p["krope"], p["lens"], p["w_kvb1"], p["w_kvb2"],
        kv_tile=p["tile"])
    np.testing.assert_allclose(np.asarray(o), np.asarray(monolithic(p)), **TOL)


def test_naive_only_equals_monolithic(problem):
    p = problem
    o = typhoon.naive_only_attention(
        p["q_nope"], p["q_rope"], p["k_shared"], p["v_shared"], p["sl"],
        p["k_n"], p["v_n"], p["lens"], kv_tile=p["tile"])
    np.testing.assert_allclose(np.asarray(o), np.asarray(monolithic(p)), **TOL)


def test_all_three_agree(problem):
    """Direct pairwise agreement (tighter than both-vs-oracle)."""
    p = problem
    o_t = typhoon.typhoon_attention(
        p["q_nope"], p["q_rope"], p["k_shared"], p["v_shared"], p["sl"],
        p["ckv"], p["krope"], p["lens"], p["w_kvb1"], p["w_kvb2"],
        kv_tile=p["tile"])
    o_a = typhoon.absorb_only_attention(
        p["q_nope"], p["q_rope"], p["ckv_shared"], p["krope_shared"], p["sl"],
        p["ckv"], p["krope"], p["lens"], p["w_kvb1"], p["w_kvb2"],
        kv_tile=p["tile"])
    o_n = typhoon.naive_only_attention(
        p["q_nope"], p["q_rope"], p["k_shared"], p["v_shared"], p["sl"],
        p["k_n"], p["v_n"], p["lens"], kv_tile=p["tile"])
    np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_a), **TOL)
    np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_n), **TOL)


def test_zero_shared_prefix_degenerates_to_absorb(rng):
    """With shared_len == 0 typhoon must equal absorb over the suffix only
    (the fall-back regime's correctness basis)."""
    p = make_problem(rng, sl=16)
    o_t = typhoon.typhoon_attention(
        p["q_nope"], p["q_rope"], p["k_shared"], p["v_shared"], 0,
        p["ckv"], p["krope"], p["lens"], p["w_kvb1"], p["w_kvb2"],
        kv_tile=p["tile"])
    q_lat = jnp.einsum("bhn,hnl->bhl", p["q_nope"], p["w_kvb1"])
    from compile.kernels import absorb as ab
    o_lat, _ = ab.absorb_batched_attention(
        q_lat, p["q_rope"], p["ckv"], p["krope"], p["lens"],
        kv_tile=p["tile"], d_qk=p["dn"] + p["dr"])
    o_a = jnp.einsum("bhl,hvl->bhv", o_lat, p["w_kvb2"])
    np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_a), **TOL)


@pytest.mark.parametrize("sl,ln", [(16, 16), (64, 16), (16, 64)])
def test_equivalence_across_shared_ratios(rng, sl, ln):
    """Equivalence holds regardless of the shared/non-shared split ratio."""
    p = make_problem(rng, sl=sl, ln=ln)
    o_t = typhoon.typhoon_attention(
        p["q_nope"], p["q_rope"], p["k_shared"], p["v_shared"], p["sl"],
        p["ckv"], p["krope"], p["lens"], p["w_kvb1"], p["w_kvb2"],
        kv_tile=p["tile"])
    np.testing.assert_allclose(np.asarray(o_t), np.asarray(monolithic(p)), **TOL)
