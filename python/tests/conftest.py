import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def randf(rng, *shape, scale=1.0):
    import jax.numpy as jnp

    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)
