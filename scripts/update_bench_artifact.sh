#!/usr/bin/env bash
# Promote a measured CI bench artifact to the tracked BENCH_sweep.json.
#
# The authoring containers ship no Rust toolchain, so the tracked perf
# trajectory is fed from CI: the `verify` and `bench-million` jobs
# upload their measured BENCH_sweep.json copies as workflow artifacts
# (`bench-sweep-measured` / `bench-million-measured`).  This script
# validates a downloaded copy — it must be real measured data, not the
# placeholder, and must carry the full schema including the
# price-cache / worker-pool fields — then installs it as the tracked
# repo-root BENCH_sweep.json for committing.
#
# Usage: scripts/update_bench_artifact.sh measured.json
#
# Three-step recipe (also in README.md):
#   1. Download `bench-million-measured` (or `bench-sweep-measured`)
#      from a green CI run on the Actions tab and unzip it.
#   2. scripts/update_bench_artifact.sh path/to/BENCH_sweep.json
#   3. Commit the updated BENCH_sweep.json.
set -euo pipefail
cd "$(dirname "$0")/.."

measured=${1:-}
[ -n "$measured" ] || {
    echo "usage: scripts/update_bench_artifact.sh measured.json" >&2
    exit 2
}
[ -f "$measured" ] || {
    echo "update_bench_artifact: $measured does not exist" >&2
    exit 1
}

# A measured artifact never carries the placeholder marker.
if grep -q '"note"' "$measured"; then
    echo "update_bench_artifact: $measured still carries the placeholder \
marker — download a *measured* CI artifact, not the tracked copy" >&2
    exit 1
fi

# Schema check: every key the trackers and CI gates read must be
# present (python3 is available wherever the CI legs run this).
python3 - "$measured" <<'EOF'
import json, sys

path = sys.argv[1]
with open(path) as f:
    d = json.load(f)

required = [
    "wall_seconds", "cells", "tokens_simulated", "threads", "backend",
    "crossover_wall_seconds", "crossover_cells",
    "cluster_wall_seconds", "cluster_cells", "cluster_row_width",
    "cluster_tokens_simulated", "cluster_migrations",
    "cluster_scale_events", "cluster_crashes", "cluster_failovers",
    "cluster_requeued", "cluster_lost_pages",
]
# The million-cell fields (including the PR 9 price-cache / pool
# counters) are required when any million field is present — the
# bench-million artifact always has them; the plain sweep artifact
# has none.
million = [
    "million_requests", "million_events", "events_per_second",
    "events_per_second_reference", "million_wall_seconds",
    "million_arena_peak", "million_arrival_rate", "million_tokens",
    "price_cache_hits", "price_cache_misses", "pool_windows",
]
missing = [k for k in required if k not in d]
if any(k in d for k in million):
    missing += [k for k in million if k not in d]
    if d.get("events_per_second", 0) <= 0:
        sys.exit("events_per_second must be positive in a measured artifact")
    if d.get("events_per_second_reference", 0) <= 0:
        sys.exit("events_per_second_reference must be positive")
    if d.get("price_cache_hits", 0) <= 0:
        sys.exit("price_cache_hits must be positive (shared surface never hit?)")
    if d.get("pool_windows", 0) <= 0:
        sys.exit("pool_windows must be positive (pooled dispatch never engaged?)")
if missing:
    sys.exit(f"measured artifact is missing required keys: {missing}")
if d.get("wall_seconds", 0) <= 0:
    sys.exit("wall_seconds must be positive in a measured artifact")
print(f"update_bench_artifact: schema OK ({len(d)} fields)")
EOF

cp "$measured" BENCH_sweep.json
echo "update_bench_artifact: installed $measured as tracked BENCH_sweep.json"
echo "commit it to make the perf trajectory real:"
echo "  git add BENCH_sweep.json && git commit -m 'Record measured bench artifact'"
