#!/usr/bin/env bash
# Tier-1 verify recipe (see ROADMAP.md).
#
# Core gate (what the CI driver runs):
#   cargo build --release && cargo test -q
# Extended gate (this script): the core gate plus formatting and lint
# cleanliness — `cargo fmt --check` and `cargo clippy -- -D warnings`.
# fmt/clippy run best-effort when their components are not installed
# (some build containers ship no rustup components, or no toolchain at
# all); the build+test gate is always hard.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: no Rust toolchain on PATH; tier-1 runs on the CI driver" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "verify: rustfmt unavailable, skipping fmt check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "verify: clippy unavailable, skipping lint" >&2
fi

echo "== detlint (determinism lint, DESIGN.md §18) =="
cargo run --release -p detlint

echo "verify: OK"
