#!/usr/bin/env bash
# Bench-artifact freshness gate (CI only — deliberately NOT part of the
# tier-1 verify recipe, which must stay runnable in toolchain-less
# containers).
#
# Diffs the measured BENCH_sweep.json the CI bench leg just produced
# against the TRACKED (committed) copy, and FAILS while the tracked
# copy still carries the no-toolchain placeholder marker — the forcing
# function that turns the perf trajectory into real data: commit the
# printed measured artifact as BENCH_sweep.json to go green.
#
# The committed copy is read via `git show HEAD:` because bench_sweep
# itself overwrites the repo-root file with measured numbers at
# runtime — the working-tree copy is already the measured one by the
# time this check runs.
#
# Usage: scripts/check_bench_artifact.sh [measured.json]
set -euo pipefail
cd "$(dirname "$0")/.."

measured=${1:-target/bench/BENCH_sweep.json}

[ -f "$measured" ] || {
    echo "check_bench_artifact: measured artifact $measured missing (run bench_sweep first)" >&2
    exit 1
}

tracked=$(mktemp)
trap 'rm -f "$tracked"' EXIT
# Test seam: CHECK_BENCH_TRACKED overrides where the tracked copy is
# read from, so the placeholder-detection path is unit-testable without
# a git checkout (rust/tests/bench_gate.rs).
if [ -n "${CHECK_BENCH_TRACKED:-}" ]; then
    cp "$CHECK_BENCH_TRACKED" "$tracked"
elif git cat-file -e HEAD:BENCH_sweep.json 2>/dev/null; then
    git show HEAD:BENCH_sweep.json >"$tracked"
else
    cp BENCH_sweep.json "$tracked"
fi

echo "== diff tracked vs measured (informational — timings vary per run) =="
diff -u "$tracked" "$measured" || true

if grep -q '"note"' "$tracked"; then
    echo "::error file=BENCH_sweep.json::tracked BENCH_sweep.json still carries the placeholder marker" >&2
    echo "--- measured artifact: commit this as BENCH_sweep.json to make the trajectory real ---"
    cat "$measured"
    exit 1
fi

echo "check_bench_artifact: tracked copy is measured data — OK"
